//! Minimal read-only `mmap(2)` wrapper (no external crates — the build is
//! fully offline, so the raw libc symbols are declared here directly).
//!
//! [`MmapRegion`] maps a whole file `PROT_READ`/`MAP_SHARED` and hands out
//! `&[u8]` views of it: the real-file backing of the store's zero-copy
//! read path. [`MmapRegion::advise`] forwards `madvise(2)` hints
//! (sequential/random access patterns from `ReadCtx.sequential`,
//! `MADV_DONTNEED` when the model's page cache evicts) so the *resident*
//! footprint of a mapping tracks the configured page-cache budget instead
//! of growing to the file size — the mechanism behind the out-of-core
//! bounded-RSS guarantee.
//!
//! Safety contract (see DESIGN.md §Store abstraction): a mapped file must
//! not be truncated or rewritten while the store holds its mapping —
//! shrinking the file would turn in-flight borrowed slices into faulting
//! references. The store only maps files it owns under its root directory
//! and never writes to a file after mapping it. `MADV_DONTNEED` on a
//! read-only shared file mapping merely drops resident pages (later
//! accesses re-fault from the file), so it is safe even while borrowed
//! slices are live.

use std::fs::File;
use std::io::Result as IoResult;

/// `madvise(2)` access-pattern hints (Linux numeric values; best-effort
/// no-ops where unsupported).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Advice {
    Normal,
    Random,
    Sequential,
    WillNeed,
    DontNeed,
}

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }

    pub const PROT_READ: c_int = 1;
    pub const MAP_SHARED: c_int = 1;
    pub const MAP_FAILED: *mut c_void = !0usize as *mut c_void;

    pub fn advice_value(a: super::Advice) -> c_int {
        match a {
            super::Advice::Normal => 0,
            super::Advice::Random => 1,
            super::Advice::Sequential => 2,
            super::Advice::WillNeed => 3,
            super::Advice::DontNeed => 4,
        }
    }
}

/// Hardware page granularity assumed for hint alignment. `madvise` demands
/// a page-aligned start address; 4 KiB divides every practical page size's
/// ancestor on the platforms we target, and an unaligned hint is rejected
/// (not corrupted) by the kernel, so a wrong guess only costs the hint.
pub const OS_PAGE: u64 = 4096;

/// A read-only shared mapping of one whole file.
#[cfg(unix)]
pub struct MmapRegion {
    ptr: *mut u8,
    len: usize,
}

#[cfg(unix)]
unsafe impl Send for MmapRegion {}
#[cfg(unix)]
unsafe impl Sync for MmapRegion {}

#[cfg(unix)]
impl MmapRegion {
    /// Map `file` (entirely). Empty files get a valid zero-length region
    /// without calling `mmap` (which rejects length 0).
    pub fn map(file: &File) -> IoResult<Self> {
        use std::os::unix::io::AsRawFd;
        let len = file.metadata()?.len() as usize;
        if len == 0 {
            return Ok(Self { ptr: std::ptr::NonNull::<u8>::dangling().as_ptr(), len: 0 });
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::MAP_FAILED {
            return Err(std::io::Error::last_os_error());
        }
        Ok(Self { ptr: ptr as *mut u8, len })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        // Safety: the region stays mapped for the lifetime of `self`, and
        // the store never mutates or truncates a file while mapped.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// Advise the whole mapping.
    pub fn advise(&self, advice: Advice) {
        self.advise_range(0, self.len as u64, advice);
    }

    /// Advise `[offset, offset+len)`, widened outward to `OS_PAGE`
    /// alignment and clamped to the mapping. Best effort: hint failures are
    /// ignored (they only affect residency, never correctness).
    pub fn advise_range(&self, offset: u64, len: u64, advice: Advice) {
        if self.len == 0 || len == 0 {
            return;
        }
        let start = (offset.min(self.len as u64) / OS_PAGE) * OS_PAGE;
        let end = offset.saturating_add(len).min(self.len as u64);
        if end <= start {
            return;
        }
        unsafe {
            let _ = sys::madvise(
                self.ptr.add(start as usize) as *mut std::os::raw::c_void,
                (end - start) as usize,
                sys::advice_value(advice),
            );
        }
    }
}

#[cfg(unix)]
impl Drop for MmapRegion {
    fn drop(&mut self) {
        if self.len > 0 {
            unsafe {
                let _ = sys::munmap(self.ptr as *mut std::os::raw::c_void, self.len);
            }
        }
    }
}

#[cfg(unix)]
impl std::fmt::Debug for MmapRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapRegion").field("len", &self.len).finish()
    }
}

/// Portable fallback: the file is read into memory once at map time. The
/// store's modeled billing is identical; only the real-RSS bound of the
/// out-of-core path needs true mappings (and is gated on unix).
#[cfg(not(unix))]
#[derive(Debug)]
pub struct MmapRegion {
    data: Vec<u8>,
}

#[cfg(not(unix))]
impl MmapRegion {
    pub fn map(file: &File) -> IoResult<Self> {
        use std::io::Read;
        let mut data = Vec::new();
        let mut f = file.try_clone()?;
        f.read_to_end(&mut data)?;
        Ok(Self { data })
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.data
    }

    pub fn advise(&self, _advice: Advice) {}

    pub fn advise_range(&self, _offset: u64, _len: u64, _advice: Advice) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp_file(name: &str, data: &[u8]) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("pg_mmap_test_{}_{}", std::process::id(), name));
        let mut f = File::create(&p).unwrap();
        f.write_all(data).unwrap();
        f.sync_all().unwrap();
        p
    }

    #[test]
    fn maps_file_contents() {
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 239) as u8).collect();
        let p = tmp_file("contents", &data);
        let f = File::open(&p).unwrap();
        let m = MmapRegion::map(&f).unwrap();
        assert_eq!(m.len(), data.len());
        assert_eq!(m.as_slice(), &data[..]);
        // Hints never affect contents.
        m.advise(Advice::Sequential);
        m.advise_range(4096, 8192, Advice::DontNeed);
        assert_eq!(m.as_slice(), &data[..]);
        drop(m);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn empty_file_maps_empty() {
        let p = tmp_file("empty", &[]);
        let f = File::open(&p).unwrap();
        let m = MmapRegion::map(&f).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.as_slice(), &[] as &[u8]);
        m.advise(Advice::Random); // no-op, must not crash
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn unaligned_hints_are_harmless() {
        let data = vec![7u8; 3 * OS_PAGE as usize + 17];
        let p = tmp_file("hints", &data);
        let f = File::open(&p).unwrap();
        let m = MmapRegion::map(&f).unwrap();
        m.advise_range(1, 1, Advice::WillNeed);
        m.advise_range(OS_PAGE + 3, 10 * OS_PAGE, Advice::DontNeed); // clamped
        m.advise_range(u64::MAX - 5, 100, Advice::Normal); // off the end
        assert_eq!(m.as_slice()[OS_PAGE as usize], 7);
        let _ = std::fs::remove_file(&p);
    }
}
