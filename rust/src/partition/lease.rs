//! Tile lease accounting for the distributed leader.
//!
//! The leader never pre-assigns tiles: workers pull leases one at a time,
//! so a fast worker naturally takes more tiles (the same work-stealing
//! shape as [`PartitionStream`](super::PartitionStream), but across
//! processes). The ledger is the single source of truth for fault
//! handling: when a worker dies or times out mid-tile, its leased tiles
//! return to the pending set and survivors pick them up on their next
//! lease — the run never hangs on a dead worker. Every return bumps the
//! tile's attempt count; once a pending tile has burned
//! `max_attempts` leases the next [`lease`](TileLedger::lease) call fails
//! loudly instead of reassigning forever.

use std::sync::Mutex;

use crate::coordinator::lock_recover;

/// Where one tile is in its lease lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Unassigned (initially, or returned by a dead worker).
    Pending,
    /// Leased to worker `w`; not yet completed.
    Leased(usize),
    /// Result received and recorded.
    Done,
}

struct TileState {
    phase: Phase,
    /// Leases ever granted for this tile (completed or not).
    attempts: usize,
}

struct LedgerInner {
    tiles: Vec<TileState>,
    /// Tiles returned to pending by `orphan_worker` (lifetime count).
    retiled: usize,
    done: usize,
}

/// Shared lease ledger over a plan's tiles (indexed by `Partition::index`).
pub struct TileLedger {
    inner: Mutex<LedgerInner>,
    max_attempts: usize,
}

impl TileLedger {
    /// A ledger with every tile pending. `max_attempts` bounds the leases
    /// any single tile may consume before the run fails loudly; it is
    /// clamped to at least 1.
    pub fn new(num_tiles: usize, max_attempts: usize) -> TileLedger {
        TileLedger {
            inner: Mutex::new(LedgerInner {
                tiles: (0..num_tiles)
                    .map(|_| TileState { phase: Phase::Pending, attempts: 0 })
                    .collect(),
                retiled: 0,
                done: 0,
            }),
            max_attempts: max_attempts.max(1),
        }
    }

    /// Lease the next pending tile to `worker`.
    ///
    /// * `Ok(Some(t))` — tile `t` is now leased to `worker`.
    /// * `Ok(None)` — nothing leasable right now: every tile is done or
    ///   leased to someone. The caller should re-poll (a lease may still
    ///   be orphaned) or finish once [`all_done`](Self::all_done).
    /// * `Err` — some pending tile has exhausted its attempt budget; the
    ///   run cannot complete and must fail loudly, not spin.
    pub fn lease(&self, worker: usize) -> Result<Option<usize>, String> {
        let mut g = lock_recover(&self.inner);
        let mut exhausted: Option<(usize, usize)> = None;
        let mut pick = None;
        for (t, tile) in g.tiles.iter().enumerate() {
            if tile.phase != Phase::Pending {
                continue;
            }
            if tile.attempts >= self.max_attempts {
                exhausted.get_or_insert((t, tile.attempts));
                continue;
            }
            pick = Some(t);
            break;
        }
        if let Some(t) = pick {
            g.tiles[t].phase = Phase::Leased(worker);
            g.tiles[t].attempts += 1;
            return Ok(Some(t));
        }
        if let Some((t, attempts)) = exhausted {
            return Err(format!(
                "tile {t} burned {attempts} leases (bound {}) without completing — \
                 giving up instead of reassigning forever",
                self.max_attempts
            ));
        }
        Ok(None)
    }

    /// Record tile `t` as completed by `worker`. Returns `false` (and
    /// records nothing) when `worker` no longer holds the lease — a
    /// result racing in after the leader already declared the worker dead
    /// and retiled must be dropped, or the tile would double-count.
    pub fn complete(&self, t: usize, worker: usize) -> bool {
        let mut g = lock_recover(&self.inner);
        if t >= g.tiles.len() || g.tiles[t].phase != Phase::Leased(worker) {
            return false;
        }
        g.tiles[t].phase = Phase::Done;
        g.done += 1;
        true
    }

    /// A worker died (EOF, timeout, kill): return all its leased tiles to
    /// pending. Returns how many tiles were orphaned.
    pub fn orphan_worker(&self, worker: usize) -> usize {
        let mut g = lock_recover(&self.inner);
        let mut n = 0;
        for tile in g.tiles.iter_mut() {
            if tile.phase == Phase::Leased(worker) {
                tile.phase = Phase::Pending;
                n += 1;
            }
        }
        g.retiled += n;
        n
    }

    pub fn all_done(&self) -> bool {
        let g = lock_recover(&self.inner);
        g.done == g.tiles.len()
    }

    /// Tiles not yet done (pending or leased).
    pub fn unfinished(&self) -> usize {
        let g = lock_recover(&self.inner);
        g.tiles.len() - g.done
    }

    /// Lifetime count of tiles returned to pending by worker loss.
    pub fn retiled(&self) -> usize {
        lock_recover(&self.inner).retiled
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leases_drain_in_order_and_complete() {
        let l = TileLedger::new(3, 3);
        assert_eq!(l.lease(0), Ok(Some(0)));
        assert_eq!(l.lease(1), Ok(Some(1)));
        assert_eq!(l.lease(0), Ok(Some(2)));
        // Everything leased: nothing to hand out, but not an error.
        assert_eq!(l.lease(1), Ok(None));
        assert!(!l.all_done());
        assert!(l.complete(0, 0));
        assert!(l.complete(1, 1));
        assert!(l.complete(2, 0));
        assert!(l.all_done());
        assert_eq!(l.unfinished(), 0);
        assert_eq!(l.retiled(), 0);
        assert_eq!(l.lease(1), Ok(None));
    }

    #[test]
    fn orphaned_tiles_go_back_to_survivors() {
        let l = TileLedger::new(2, 3);
        assert_eq!(l.lease(0), Ok(Some(0)));
        assert_eq!(l.lease(1), Ok(Some(1)));
        // Worker 0 dies mid-tile; its tile must come back.
        assert_eq!(l.orphan_worker(0), 1);
        assert_eq!(l.retiled(), 1);
        assert_eq!(l.lease(1), Ok(Some(0)));
        assert!(l.complete(0, 1));
        assert!(l.complete(1, 1));
        assert!(l.all_done());
    }

    #[test]
    fn stale_completion_from_declared_dead_worker_is_dropped() {
        let l = TileLedger::new(1, 3);
        assert_eq!(l.lease(0), Ok(Some(0)));
        assert_eq!(l.orphan_worker(0), 1);
        // Worker 0's result arrives after the leader gave up on it.
        assert!(!l.complete(0, 0));
        assert!(!l.all_done());
        // The tile is re-leased and completed by the survivor.
        assert_eq!(l.lease(1), Ok(Some(0)));
        assert!(l.complete(0, 1));
        assert!(l.all_done());
    }

    #[test]
    fn attempt_budget_bounds_reassignment() {
        let l = TileLedger::new(1, 2);
        for w in 0..2 {
            assert_eq!(l.lease(w), Ok(Some(0)));
            assert_eq!(l.orphan_worker(w), 1);
        }
        // Third lease of the same tile exceeds the bound: loud error.
        let err = l.lease(2).unwrap_err();
        assert!(err.contains("tile 0"), "unexpected message: {err}");
        assert!(err.contains("bound 2"), "unexpected message: {err}");
        assert_eq!(l.retiled(), 2);
        assert_eq!(l.unfinished(), 1);
    }

    #[test]
    fn exhausted_tile_does_not_block_other_tiles() {
        let l = TileLedger::new(2, 1);
        assert_eq!(l.lease(0), Ok(Some(0)));
        assert_eq!(l.orphan_worker(0), 1);
        // Tile 0 is exhausted, but tile 1 is still leasable: the error
        // only fires once no progress is possible.
        assert_eq!(l.lease(1), Ok(Some(1)));
        assert!(l.complete(1, 1));
        assert!(l.lease(1).is_err());
    }

    #[test]
    fn double_complete_is_dropped() {
        let l = TileLedger::new(1, 3);
        assert_eq!(l.lease(0), Ok(Some(0)));
        assert!(l.complete(0, 0));
        assert!(!l.complete(0, 0));
        assert!(l.all_done());
    }
}
