//! The partitioned-request subsystem (§2's partitioned CSX/COO request
//! families).
//!
//! The paper promises partition-granular loading for shared- and
//! distributed-memory frameworks: a consumer (a GAPBS-style process, a
//! cluster "machine", a NUMA worker) asks for *its* share of the graph and
//! the library serves every share concurrently, overlapping loading with
//! the consumer's computation. Three pieces implement that here:
//!
//! * **Planner** ([`PartitionPlan`]) — edge-balanced 1D (vertex-range) and
//!   2D (source×target tile) plans plus exact edge-split COO plans, all
//!   computed in O(p log n) from the Elias–Fano offsets index
//!   (`edge_partition_point`) — the sidecar-only partitioning the paper's
//!   §6 calls "loading from storage instead of processing". Plans carry
//!   serializable metadata ([`PartitionPlan::to_json`]) so a leader can
//!   compute once and ship shares to machines.
//! * **Server** (coordinator `PgGraph::{csx,coo}_get_partitions`) — decodes
//!   partitions *ahead* of consumption into a bounded staging window sized
//!   by the §3 [`LoadModel`](crate::model::LoadModel) (see
//!   [`prefetch_depth`]), with decode concurrency backpressured through
//!   the coordinator's condvar [`BufferPool`](crate::coordinator::buffer).
//! * **Consumers** ([`stream::PartitionStream`]) — a pull-based,
//!   multi-consumer iterator with work-stealing hand-off: any number of
//!   consumer threads drain the same stream, each `next()` handing out the
//!   next staged partition. `algorithms::partitioned` ports BFS / WCC /
//!   Afforest on top of it so computation runs *while* later partitions
//!   load.

pub mod lease;
pub mod stream;

pub use lease::TileLedger;
pub use stream::{LoadedPartition, PartitionStream, StreamCounters};

use anyhow::{bail, Result};

use crate::coordinator::VertexRange;
use crate::formats::webgraph::WgOffsets;
use crate::model::LoadModel;
use crate::util::json::Json;

/// How a [`PartitionPlan`] tiles the edge set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanKind {
    /// Vertex-aligned 1D split: each partition owns a consecutive source
    /// vertex range (all of its rows' edges).
    OneD,
    /// 2D tiling: source-dimension edge-balanced row groups × even
    /// target-vertex column ranges. Partition `(r, c)` owns the edges with
    /// source in row group `r` *and* target in column range `c`.
    TwoD { rows: usize, cols: usize },
    /// Exact edge split (COO view): partition `k` owns global edges
    /// `[m·k/p, m·(k+1)/p)`, cutting inside a vertex's list if needed.
    Coo,
}

/// One partition of a plan — pure sidecar metadata, no graph data.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Partition {
    /// Position in the plan (stable across delivery order).
    pub index: usize,
    /// Source-vertex range covering this partition's edges.
    pub vertices: VertexRange,
    /// Global edge span `[start, end)`. For 1D/2D this is the *row* span of
    /// `vertices` (a 2D tile's actual edge count is only known after
    /// decode); for COO plans it is exact and edges outside it are trimmed.
    pub edge_span: (u64, u64),
    /// Target-vertex (column) range; `[0, n)` except for 2D tiles.
    pub targets: VertexRange,
}

impl Partition {
    /// Edges of the row span (exact for 1D/COO; an upper bound for 2D).
    pub fn span_edges(&self) -> u64 {
        self.edge_span.1 - self.edge_span.0
    }
}

/// An edge-balanced partition plan over one graph, computed from the
/// offsets sidecar alone in O(p log n).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionPlan {
    pub kind: PlanKind,
    pub num_vertices: usize,
    pub num_edges: u64,
    pub parts: Vec<Partition>,
}

/// Split `[0, n)` into `groups` source ranges of ~equal edge mass using
/// O(groups · log n) Elias–Fano partition-point searches. Boundaries are
/// monotone even on graphs with empty-vertex runs or extreme hubs (a hub
/// heavier than `m/groups` simply gets a singleton group).
fn edge_balanced_rows(offsets: &WgOffsets, n: usize, m: u64, groups: usize) -> Vec<usize> {
    let groups = groups.max(1);
    let mut bounds = Vec::with_capacity(groups + 1);
    bounds.push(0usize);
    for k in 1..groups {
        let target = m * k as u64 / groups as u64;
        // First vertex whose cumulative edge offset reaches the target.
        let v = offsets.edge_partition_point(|e| e < target).min(n);
        let prev = *bounds.last().expect("non-empty bounds");
        bounds.push(v.max(prev));
    }
    bounds.push(n);
    bounds
}

impl PartitionPlan {
    /// Edge-balanced 1D plan: `parts` consecutive source-vertex ranges with
    /// ~`m/parts` edges each (vertex-aligned; the partitioned counterpart
    /// of `csx_get_subgraph`).
    pub fn one_d(offsets: &WgOffsets, parts: usize) -> Self {
        let n = offsets.num_vertices();
        let m = offsets.num_edges();
        let bounds = edge_balanced_rows(offsets, n, m, parts);
        let plan_parts = bounds
            .windows(2)
            .enumerate()
            .map(|(index, w)| Partition {
                index,
                vertices: VertexRange::new(w[0], w[1]),
                edge_span: (offsets.edge_offset(w[0]), offsets.edge_offset(w[1])),
                targets: VertexRange::new(0, n),
            })
            .collect();
        Self { kind: PlanKind::OneD, num_vertices: n, num_edges: m, parts: plan_parts }
    }

    /// 2D plan: `rows` edge-balanced source row groups × `cols` even
    /// target-vertex columns, row-major. Every edge lands in exactly one
    /// tile (its source row group × its target column).
    pub fn two_d(offsets: &WgOffsets, rows: usize, cols: usize) -> Self {
        let n = offsets.num_vertices();
        let m = offsets.num_edges();
        let (rows, cols) = (rows.max(1), cols.max(1));
        let row_bounds = edge_balanced_rows(offsets, n, m, rows);
        let mut parts = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            let vertices = VertexRange::new(row_bounds[r], row_bounds[r + 1]);
            let edge_span =
                (offsets.edge_offset(vertices.start), offsets.edge_offset(vertices.end));
            for c in 0..cols {
                let (t0, t1) = crate::util::chunk_range(n, cols, c);
                parts.push(Partition {
                    index: r * cols + c,
                    vertices,
                    edge_span,
                    targets: VertexRange::new(t0, t1),
                });
            }
        }
        Self { kind: PlanKind::TwoD { rows, cols }, num_vertices: n, num_edges: m, parts }
    }

    /// Exact edge-split COO plan: partition `k` owns edges
    /// `[m·k/p, m·(k+1)/p)` regardless of vertex boundaries — the finest
    /// granularity of §4.2, perfectly balanced by construction.
    pub fn coo(offsets: &WgOffsets, parts: usize) -> Self {
        let n = offsets.num_vertices();
        let m = offsets.num_edges();
        let parts_n = parts.max(1);
        let plan_parts = (0..parts_n)
            .map(|k| {
                let e0 = m * k as u64 / parts_n as u64;
                let e1 = m * (k + 1) as u64 / parts_n as u64;
                // Covering source-vertex span of [e0, e1): the row holding
                // edge e0 through the row holding edge e1 - 1 (inclusive).
                let (v0, v1) = if e0 == e1 {
                    (0, 0)
                } else {
                    let v0 = offsets.edge_partition_point(|e| e <= e0).saturating_sub(1);
                    let v1 = offsets.edge_partition_point(|e| e < e1).min(n);
                    (v0, v1)
                };
                Partition {
                    index: k,
                    vertices: VertexRange::new(v0, v1),
                    edge_span: (e0, e1),
                    targets: VertexRange::new(0, n),
                }
            })
            .collect();
        Self { kind: PlanKind::Coo, num_vertices: n, num_edges: m, parts: plan_parts }
    }

    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    /// Edge-balance quality: max partition edge mass over the ideal
    /// `m / parts` (1.0 = perfect). For 2D plans the row-span mass is
    /// divided evenly over the row's tiles — the planner's *intent*, since
    /// per-tile counts need a decode. ∞-free: empty graphs report 1.0.
    pub fn balance_factor(&self) -> f64 {
        if self.num_edges == 0 || self.parts.is_empty() {
            return 1.0;
        }
        let ideal = self.num_edges as f64 / self.parts.len() as f64;
        let max_mass = match self.kind {
            PlanKind::TwoD { cols, .. } => self
                .parts
                .iter()
                .map(|p| p.span_edges() as f64 / cols as f64)
                .fold(0.0, f64::max),
            _ => self.parts.iter().map(|p| p.span_edges() as f64).fold(0.0, f64::max),
        };
        max_mass / ideal
    }

    /// Validate internal consistency: spans within bounds, and — the
    /// exactly-once guarantee — the edge spans *tile* `[0, m)`
    /// contiguously (1D/COO; plus vertex-range tiling for 1D) or form a
    /// proper row-major grid whose columns tile `[0, n)` per row group
    /// (2D). A sum-only check would accept overlapping or gapped foreign
    /// plans, which the server would then serve as silent double-delivery
    /// / edge loss. Used by tests, `get_partitions`, and consumers
    /// receiving a deserialized plan.
    pub fn check(&self) -> Result<()> {
        for (i, p) in self.parts.iter().enumerate() {
            if p.index != i {
                bail!("partition {i} carries index {}", p.index);
            }
            if p.vertices.start > p.vertices.end || p.vertices.end > self.num_vertices {
                bail!("partition {i}: bad vertex range");
            }
            if p.edge_span.0 > p.edge_span.1 || p.edge_span.1 > self.num_edges {
                bail!("partition {i}: bad edge span");
            }
            if p.targets.start > p.targets.end || p.targets.end > self.num_vertices {
                bail!("partition {i}: bad target range");
            }
        }
        match self.kind {
            PlanKind::OneD | PlanKind::Coo => {
                if self.parts.is_empty() {
                    bail!("empty plan");
                }
                // Edge spans must tile [0, m) contiguously — not just sum
                // to m — and only 2D tiles may narrow the target columns
                // (a narrowed 1D/COO partition would silently drop edges
                // at decode time).
                let mut cursor = 0u64;
                for p in &self.parts {
                    if p.edge_span.0 != cursor {
                        bail!(
                            "partition {}: edge span starts at {} (expected {cursor})",
                            p.index,
                            p.edge_span.0
                        );
                    }
                    cursor = p.edge_span.1;
                    if p.targets.start != 0 || p.targets.end != self.num_vertices {
                        bail!(
                            "partition {}: {:?} plans must carry full targets",
                            p.index,
                            self.kind
                        );
                    }
                }
                if cursor != self.num_edges {
                    bail!("plan covers {cursor} of {} edges", self.num_edges);
                }
                if matches!(self.kind, PlanKind::OneD) {
                    // 1D additionally tiles the vertex space (complete
                    // rows per partition).
                    let mut v = 0usize;
                    for p in &self.parts {
                        if p.vertices.start != v {
                            bail!("partition {}: vertex range not contiguous", p.index);
                        }
                        v = p.vertices.end;
                    }
                    if v != self.num_vertices {
                        bail!("1D plan covers vertices 0..{v} of {}", self.num_vertices);
                    }
                }
            }
            PlanKind::TwoD { rows, cols } => {
                // checked_mul: a foreign plan's grid dims are untrusted and
                // must not panic the validator itself on overflow.
                if rows == 0 || cols == 0 || rows.checked_mul(cols) != Some(self.parts.len()) {
                    bail!(
                        "2D plan has {} tiles, expected {rows}×{cols} (both nonzero)",
                        self.parts.len()
                    );
                }
                let mut row_v = 0usize;
                let mut row_e = 0u64;
                for r in 0..rows {
                    let row = &self.parts[r * cols..(r + 1) * cols];
                    // Row groups tile the vertex/edge space contiguously.
                    if row[0].vertices.start != row_v || row[0].edge_span.0 != row_e {
                        bail!("row group {r}: not contiguous with predecessor");
                    }
                    row_v = row[0].vertices.end;
                    row_e = row[0].edge_span.1;
                    // Tiles of one row share its range; columns tile [0, n).
                    let mut col = 0usize;
                    for t in row {
                        if t.vertices != row[0].vertices || t.edge_span != row[0].edge_span {
                            bail!("tile {}: row metadata mismatch", t.index);
                        }
                        if t.targets.start != col {
                            bail!("tile {}: target columns not contiguous", t.index);
                        }
                        col = t.targets.end;
                    }
                    if col != self.num_vertices {
                        bail!("row group {r}: columns cover 0..{col} of {}", self.num_vertices);
                    }
                }
                if row_v != self.num_vertices || row_e != self.num_edges {
                    bail!("2D row groups cover {row_v}v/{row_e}e of the graph");
                }
            }
        }
        Ok(())
    }

    /// Parse a plan previously serialized with [`Self::to_json`] — the
    /// cross-process leg of leader→machine plan shipping: the leader plans
    /// once off its sidecar, serializes, and every machine reconstructs the
    /// identical plan without touching the offsets index. The parsed plan
    /// passes the full [`Self::check`] tiling validation before it is
    /// returned, so an overlapping/gapped/truncated foreign document is
    /// rejected here rather than served as silent double-delivery. The
    /// derived `balance_factor` field in the document is ignored
    /// (recomputed on demand).
    pub fn from_json(doc: &Json) -> Result<Self> {
        fn usize_field(doc: &Json, key: &str) -> Result<usize> {
            Ok(u64_field(doc, key)? as usize)
        }
        fn u64_field(doc: &Json, key: &str) -> Result<u64> {
            let v = doc
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("plan json: missing numeric {key:?}"))?;
            num_to_u64(v).ok_or_else(|| anyhow::anyhow!("plan json: bad {key:?} value {v}"))
        }
        fn num_to_u64(v: f64) -> Option<u64> {
            // Integral, non-negative, and inside f64's exact-integer range.
            if v.fract() == 0.0 && (0.0..=9007199254740992.0).contains(&v) {
                Some(v as u64)
            } else {
                None
            }
        }
        fn pair(doc: &Json, key: &str) -> Result<(u64, u64)> {
            let arr = doc
                .get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("plan json: missing pair {key:?}"))?;
            let [a, b] = arr else {
                bail!("plan json: {key:?} must be a 2-element array");
            };
            let (a, b) = (a.as_f64().and_then(num_to_u64), b.as_f64().and_then(num_to_u64));
            let (Some(a), Some(b)) = (a, b) else {
                bail!("plan json: non-integer bound in {key:?}");
            };
            Ok((a, b))
        }

        let kind_s = doc
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("plan json: missing \"kind\""))?;
        let kind = match kind_s {
            "1d" => PlanKind::OneD,
            "coo" => PlanKind::Coo,
            other => {
                let dims = other
                    .strip_prefix("2d:")
                    .and_then(|d| d.split_once('x'))
                    .and_then(|(r, c)| r.parse::<usize>().ok().zip(c.parse::<usize>().ok()));
                match dims {
                    // Overflow-check the grid size here so `check()`'s
                    // `rows * cols` below stays panic-free on tampered
                    // documents.
                    Some((rows, cols)) if rows.checked_mul(cols).is_some() => {
                        PlanKind::TwoD { rows, cols }
                    }
                    Some(_) => bail!("plan json: 2d grid size overflows"),
                    None => bail!("plan json: unknown kind {other:?}"),
                }
            }
        };
        let num_vertices = usize_field(doc, "num_vertices")?;
        let num_edges = u64_field(doc, "num_edges")?;
        let parts_json = doc
            .get("parts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("plan json: missing \"parts\" array"))?;
        let mut parts = Vec::with_capacity(parts_json.len());
        for (index, p) in parts_json.iter().enumerate() {
            let (v0, v1) = pair(p, "v")?;
            let (e0, e1) = pair(p, "e")?;
            let (t0, t1) = pair(p, "t")?;
            parts.push(Partition {
                index,
                vertices: VertexRange::new(v0 as usize, v1 as usize),
                edge_span: (e0, e1),
                targets: VertexRange::new(t0 as usize, t1 as usize),
            });
        }
        let plan = Self { kind, num_vertices, num_edges, parts };
        plan.check()?;
        Ok(plan)
    }

    /// Serializable plan metadata (for a leader to ship to machines, and
    /// for the CI metrics).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        let kind = match self.kind {
            PlanKind::OneD => "1d".to_string(),
            PlanKind::TwoD { rows, cols } => format!("2d:{rows}x{cols}"),
            PlanKind::Coo => "coo".to_string(),
        };
        o.set("kind", kind)
            .set("num_vertices", self.num_vertices as f64)
            .set("num_edges", self.num_edges as f64)
            .set("balance_factor", self.balance_factor());
        let pair = |a: f64, b: f64| Json::Arr(vec![Json::Num(a), Json::Num(b)]);
        let mut arr = Json::Arr(vec![]);
        for p in &self.parts {
            let mut e = Json::obj();
            e.set("v", pair(p.vertices.start as f64, p.vertices.end as f64))
                .set("e", pair(p.edge_span.0 as f64, p.edge_span.1 as f64))
                .set("t", pair(p.targets.start as f64, p.targets.end as f64));
            arr.push(e);
        }
        o.set("parts", arr);
        o
    }
}

/// Model-driven prefetch depth: how many partitions the server stages
/// ahead of consumption.
///
/// With load bandwidth `b = min(σ·r, d)` (§3's upper bound — what the
/// staging pipeline can deliver) and the consumers' aggregate processing
/// bandwidth `consume_bps` (uncompressed bytes/s), the loader can run
/// `b / consume_bps` partitions ahead per partition consumed. Staging that
/// many (+1 so the pipeline never starves between hand-offs) keeps both
/// sides busy; staging more only buys memory pressure. On a slow tier
/// (HDD: `b < consume`) the depth bottoms out at 2 — the loader cannot
/// fill a deeper window anyway; on DRAM-class tiers it grows until
/// `max_depth` (the memory budget, typically tied to the buffer count)
/// caps it.
pub fn prefetch_depth(model: &LoadModel, consume_bps: f64, max_depth: usize) -> usize {
    let max_depth = max_depth.max(1);
    if consume_bps <= 0.0 {
        return max_depth;
    }
    let ratio = model.upper_bound() / consume_bps;
    if !ratio.is_finite() {
        return max_depth;
    }
    ((ratio.ceil() as usize).saturating_add(1)).clamp(1, max_depth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::webgraph;
    use crate::graph::generators;
    use crate::graph::CsrGraph;

    fn offsets_of(g: &CsrGraph) -> WgOffsets {
        let (_, bit_offsets, _) = webgraph::compress(g, webgraph::WgParams::default());
        WgOffsets::from_vecs(&bit_offsets, &g.offsets).expect("offsets")
    }

    #[test]
    fn one_d_tiles_edges_exactly() {
        for (gi, g) in [
            generators::barabasi_albert(800, 6, 3),
            generators::rmat(9, 6, 5), // skewed
            CsrGraph::from_edges(50, &[(0, 1), (0, 2), (49, 0)]), // mostly empty vertices
            CsrGraph::from_edges(10, &[]),                        // edgeless
        ]
        .into_iter()
        .enumerate()
        {
            let offs = offsets_of(&g);
            for parts in [1usize, 2, 3, 7, 16, 100] {
                let plan = PartitionPlan::one_d(&offs, parts);
                plan.check().unwrap_or_else(|e| panic!("graph {gi} parts {parts}: {e}"));
                assert_eq!(plan.num_parts(), parts.max(1));
                // Ranges tile [0, n).
                assert_eq!(plan.parts[0].vertices.start, 0);
                assert_eq!(plan.parts.last().unwrap().vertices.end, g.num_vertices());
                for w in plan.parts.windows(2) {
                    assert_eq!(w[0].vertices.end, w[1].vertices.start);
                }
                assert!(plan.balance_factor() >= 1.0 - 1e-9);
            }
        }
    }

    #[test]
    fn coo_split_is_perfectly_balanced() {
        let g = generators::rmat(9, 8, 7);
        let offs = offsets_of(&g);
        for parts in [1usize, 3, 8, 33] {
            let plan = PartitionPlan::coo(&offs, parts);
            plan.check().unwrap();
            let max = plan.parts.iter().map(|p| p.span_edges()).max().unwrap();
            let min = plan.parts.iter().map(|p| p.span_edges()).min().unwrap();
            assert!(max - min <= 1, "parts {parts}: {min}..{max}");
            // Max share is ceil(m/p) ⇒ factor ≤ 1 + p/m.
            assert!(
                plan.balance_factor() <= 1.0 + parts as f64 / plan.num_edges as f64 + 1e-9
            );
        }
    }

    #[test]
    fn two_d_rows_and_columns_tile_the_square() {
        let g = generators::barabasi_albert(600, 5, 11);
        let offs = offsets_of(&g);
        let plan = PartitionPlan::two_d(&offs, 3, 4);
        plan.check().unwrap();
        assert_eq!(plan.num_parts(), 12);
        // Row-major tiles: every row group repeats over all 4 columns, and
        // the columns tile [0, n) exactly.
        for r in 0..3 {
            let row = &plan.parts[r * 4..(r + 1) * 4];
            assert_eq!(row[0].targets.start, 0);
            assert_eq!(row[3].targets.end, g.num_vertices());
            for w in row.windows(2) {
                assert_eq!(w[0].vertices, w[1].vertices);
                assert_eq!(w[0].targets.end, w[1].targets.start);
            }
        }
    }

    #[test]
    fn planning_uses_only_the_sidecar_and_balances_skew() {
        // A hub-heavy graph: balance must stay within 2× ideal when the
        // hub itself is lighter than one share.
        let g = generators::barabasi_albert(4000, 8, 17);
        let offs = offsets_of(&g);
        let plan = PartitionPlan::one_d(&offs, 8);
        plan.check().unwrap();
        assert!(
            plan.balance_factor() < 2.0,
            "1D balance factor {} too skewed",
            plan.balance_factor()
        );
    }

    #[test]
    fn check_rejects_overlapping_and_gapped_plans() {
        let g = generators::barabasi_albert(300, 4, 3);
        let offs = offsets_of(&g);
        let good = PartitionPlan::one_d(&offs, 4);
        good.check().unwrap();
        // Overlap: duplicate the first partition's span into the second —
        // sums still equal m for a crafted pair, but tiling is violated.
        let mut overlap = good.clone();
        let first = overlap.parts[0];
        overlap.parts[1].edge_span = first.edge_span;
        overlap.parts[1].vertices = first.vertices;
        assert!(overlap.check().is_err(), "overlapping spans must be rejected");
        // Gap: shift a boundary without fixing the neighbor.
        let mut gap = good.clone();
        gap.parts[2].edge_span.0 += 1;
        assert!(gap.check().is_err(), "gapped spans must be rejected");
        // Degenerate 2D shapes.
        let empty2d = PartitionPlan {
            kind: PlanKind::TwoD { rows: 2, cols: 0 },
            num_vertices: g.num_vertices(),
            num_edges: g.num_edges(),
            parts: Vec::new(),
        };
        assert!(empty2d.check().is_err(), "rows×0 grid must be rejected");
        let empty = PartitionPlan {
            kind: PlanKind::OneD,
            num_vertices: g.num_vertices(),
            num_edges: g.num_edges(),
            parts: Vec::new(),
        };
        assert!(empty.check().is_err(), "empty 1D plan over a nonempty graph");
    }

    #[test]
    fn plan_json_metadata() {
        let g = generators::barabasi_albert(200, 4, 5);
        let offs = offsets_of(&g);
        let plan = PartitionPlan::two_d(&offs, 2, 2);
        let s = plan.to_json().to_string_pretty();
        assert!(s.contains("\"kind\""), "{s}");
        assert!(s.contains("2d:2x2"), "{s}");
        assert!(s.contains("\"balance_factor\""), "{s}");
        assert!(s.contains("\"parts\""), "{s}");
    }

    #[test]
    fn plan_json_round_trips_through_text() {
        // The leader→machine shipping path: plan → to_json → text →
        // Json::parse → from_json must reconstruct the identical plan, for
        // every plan kind on every graph shape.
        for g in [
            generators::barabasi_albert(700, 6, 9),
            generators::rmat(8, 5, 3),
            CsrGraph::from_edges(40, &[(0, 1), (39, 0), (7, 8)]),
            CsrGraph::from_edges(5, &[]),
        ] {
            let offs = offsets_of(&g);
            let plans = [
                PartitionPlan::one_d(&offs, 1),
                PartitionPlan::one_d(&offs, 7),
                PartitionPlan::two_d(&offs, 3, 4),
                PartitionPlan::two_d(&offs, 1, 1),
                PartitionPlan::coo(&offs, 5),
            ];
            for plan in plans {
                let text = plan.to_json().to_string_pretty();
                let doc = crate::util::json::Json::parse(&text).expect("parse");
                let back = PartitionPlan::from_json(&doc)
                    .unwrap_or_else(|e| panic!("{:?}: {e}", plan.kind));
                assert_eq!(back, plan, "kind {:?}", plan.kind);
            }
        }
    }

    #[test]
    fn from_json_rejects_tampered_plans() {
        let g = generators::barabasi_albert(300, 4, 3);
        let offs = offsets_of(&g);
        let plan = PartitionPlan::one_d(&offs, 4);

        // An overlapping span (a would-be double delivery) fails check().
        let mut overlap = plan.clone();
        overlap.parts[1].edge_span = overlap.parts[0].edge_span;
        overlap.parts[1].vertices = overlap.parts[0].vertices;
        let doc = Json::parse(&overlap.to_json().to_string_pretty()).unwrap();
        assert!(PartitionPlan::from_json(&doc).is_err());

        // Structural damage: missing fields, bad kind, non-integer bounds.
        let good_text = plan.to_json().to_string_pretty();
        let missing = Json::parse(&good_text.replace("\"kind\"", "\"knid\"")).unwrap();
        assert!(PartitionPlan::from_json(&missing).is_err());
        let bad_kind = Json::parse(&good_text.replace("\"1d\"", "\"9d\"")).unwrap();
        assert!(PartitionPlan::from_json(&bad_kind).is_err());
        // A 2d grid whose rows×cols product overflows usize must be
        // refused, not panic the validator.
        let huge = good_text.replace("\"1d\"", "\"2d:4294967296x4294967296\"");
        let huge2d = Json::parse(&huge).unwrap();
        assert!(PartitionPlan::from_json(&huge2d).is_err());
        let mut frac = Json::parse(&good_text).unwrap();
        frac.set("num_edges", 1.5);
        assert!(PartitionPlan::from_json(&frac).is_err());
        assert!(PartitionPlan::from_json(&Json::Null).is_err());
    }

    #[test]
    fn prefetch_depth_tracks_the_storage_tier() {
        use crate::model::LoadModel;
        let consume = 400e6; // consumer eats 400 MB/s of uncompressed CSR
        let hdd = LoadModel { sigma: 160e6, r: 5.0, d: 1e9 };
        let ssd = LoadModel { sigma: 3.6e9, r: 5.0, d: 4e9 };
        let dram = LoadModel { sigma: 18e9, r: 5.0, d: 8e9 };
        let d_hdd = prefetch_depth(&hdd, consume, 64);
        let d_ssd = prefetch_depth(&ssd, consume, 64);
        let d_dram = prefetch_depth(&dram, consume, 64);
        assert!(d_hdd <= d_ssd && d_ssd <= d_dram, "{d_hdd} {d_ssd} {d_dram}");
        assert!(d_hdd >= 2, "even a slow tier keeps one partition staged ahead");
        // The memory cap binds on fast tiers.
        assert_eq!(prefetch_depth(&dram, consume, 8), 8);
        // Degenerate inputs stay sane.
        assert_eq!(prefetch_depth(&hdd, 0.0, 16), 16);
        let uncompressed = LoadModel { sigma: 1e9, r: 1.0, d: f64::INFINITY };
        assert!(prefetch_depth(&uncompressed, 1e9, 16) >= 2);
    }
}
