//! Pull-based partition delivery: a bounded staging queue between the
//! coordinator's partition server (producer side) and any number of
//! consumer threads.
//!
//! The hand-off is *work-stealing*: consumers share one `next()` — whoever
//! calls first takes the next staged partition, so a slow consumer never
//! blocks the others (the multi-consumer drain the GAP/Ammar–Özsu-style
//! evaluations need). Backpressure is two-level: decode concurrency is
//! bounded by the coordinator's condvar
//! [`BufferPool`](crate::coordinator::buffer::BufferPool) (a partition
//! decode holds a buffer), and *staging depth* — decoded-but-unconsumed
//! partitions — is bounded by the prefetch window
//! ([`prefetch_depth`](super::prefetch_depth)): the producer parks on the
//! stream's condvar when the window is full and is woken by the next
//! consume.
//!
//! [`StreamCounters`] records the interleaving quality: a `next()` served
//! from a non-empty window is a *prefetch hit* (the consumer never waited
//! on storage); producer stalls count window-full backpressure events.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Result};

use super::Partition;
use crate::coordinator::lock_recover;
use crate::formats::webgraph::DecodedBlock;
use crate::graph::VertexId;
use crate::obs::{self, Counter};

/// One delivered partition: its plan metadata plus the decoded CSR slice
/// (rows of `part.vertices`, edges filtered to `part.targets` for 2D tiles
/// and trimmed to `part.edge_span` for COO plans). Owned by the consumer —
/// the library buffer was recycled at hand-off.
#[derive(Debug)]
pub struct LoadedPartition {
    pub part: Partition,
    pub block: DecodedBlock,
}

impl LoadedPartition {
    pub fn num_edges(&self) -> u64 {
        self.block.num_edges()
    }

    /// Iterate the partition's `(src, dst)` pairs.
    pub fn iter_edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        let first = self.block.first_vertex;
        (0..self.block.num_vertices()).flat_map(move |i| {
            let v = (first + i) as VertexId;
            self.block.neighbors(i).iter().map(move |&d| (v, d))
        })
    }

    /// Successors of global vertex `v` within this partition (the rows of
    /// 1D partitions are complete adjacency lists; 2D/COO rows are the
    /// tile's filtered view).
    pub fn neighbors(&self, v: usize) -> &[VertexId] {
        self.block.neighbors(v - self.block.first_vertex)
    }
}

/// Interleaving counters of one stream (cumulative, race-tolerant).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamCounters {
    /// Partitions staged by the producer.
    pub produced: u64,
    /// Partitions handed to consumers.
    pub consumed: u64,
    /// `next()` calls served without waiting (window non-empty).
    pub prefetch_hits: u64,
    /// `next()` calls that had to park for the producer.
    pub consumer_stalls: u64,
    /// Producer waits on a full window (consumers were the bottleneck).
    pub producer_stalls: u64,
}

impl StreamCounters {
    /// Fraction of consumer pulls that never touched storage latency.
    pub fn prefetch_hit_rate(&self) -> f64 {
        let total = self.prefetch_hits + self.consumer_stalls;
        if total == 0 {
            0.0
        } else {
            self.prefetch_hits as f64 / total as f64
        }
    }
}

/// Registry mirrors of the per-stream counters: handles resolved from the
/// owning graph's [`MetricsRegistry`](crate::obs::MetricsRegistry), so
/// stream health shows up in one mergeable snapshot alongside everything
/// else. Detached (no-op aggregation) for streams built outside a
/// coordinator. The per-stream [`StreamCounters`] stay authoritative for
/// `counters()` — the mirrors are cumulative per registry, not per stream.
#[derive(Debug, Clone, Default)]
pub struct StreamObs {
    pub produced: Counter,
    pub consumed: Counter,
    pub prefetch_hits: Counter,
    pub consumer_stalls: Counter,
    pub producer_stalls: Counter,
}

#[derive(Debug, Default)]
struct StreamState {
    ready: VecDeque<LoadedPartition>,
    /// Window slots reserved by the producer (in-flight decodes + staged).
    scheduled: usize,
    /// Partitions pushed so far (staged + already consumed).
    produced: usize,
    /// Partitions handed out.
    consumed: usize,
    /// Producer finished (all partitions staged, or bailed on cancel).
    done_producing: bool,
    /// First decode failure; poisons the stream.
    failed: Option<String>,
    /// The failure was a *shutdown* (graph released / buffer pool closed),
    /// not a decode error: consumers then see a typed
    /// [`PgError::Closed`](crate::coordinator::PgError) so a serving layer
    /// can tell graceful churn apart from data corruption.
    failed_closed: bool,
}

/// Shared core of a [`PartitionStream`] (producer and consumers both hold
/// an `Arc`).
#[derive(Debug)]
pub struct StreamShared {
    state: Mutex<StreamState>,
    /// Consumers park here for items; the producer parks here for window
    /// space. Both directions notify on every transition.
    cv: Condvar,
    window: usize,
    total: usize,
    cancelled: AtomicBool,
    hits: AtomicU64,
    consumer_stalls: AtomicU64,
    producer_stalls: AtomicU64,
    obs: StreamObs,
}

impl StreamShared {
    pub(crate) fn new(total: usize, window: usize) -> Arc<Self> {
        Self::new_with_obs(total, window, StreamObs::default())
    }

    /// Coordinator constructor: mirror counters into the graph registry.
    pub(crate) fn new_with_obs(total: usize, window: usize, obs: StreamObs) -> Arc<Self> {
        Arc::new(Self {
            // A zero-partition stream is born exhausted — consumers must
            // see Ok(None), not park for pushes that will never come.
            state: Mutex::new(StreamState { done_producing: total == 0, ..Default::default() }),
            cv: Condvar::new(),
            window: window.max(1),
            total,
            cancelled: AtomicBool::new(false),
            hits: AtomicU64::new(0),
            consumer_stalls: AtomicU64::new(0),
            producer_stalls: AtomicU64::new(0),
            obs,
        })
    }

    /// Producer: block until a staging-window slot is free, then *reserve*
    /// it (or return false when producing should stop). The reservation
    /// counts in-flight decodes as well as staged partitions, so the
    /// dispatcher can never run more than `window` partitions ahead of
    /// consumption even while every decode is still on a worker.
    pub(crate) fn wait_for_window(&self) -> bool {
        let t0 = std::time::Instant::now();
        let mut g = lock_recover(&self.state);
        let mut stalled = false;
        let result = loop {
            if self.cancelled.load(Ordering::Acquire) || g.failed.is_some() {
                break false;
            }
            if g.scheduled.saturating_sub(g.consumed) < self.window {
                g.scheduled += 1;
                break true;
            }
            if !stalled {
                stalled = true;
                self.producer_stalls.fetch_add(1, Ordering::Relaxed);
                self.obs.producer_stalls.inc();
            }
            g = self.cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner);
        };
        drop(g);
        if stalled {
            obs::tracer().record("stream", "producer-stall", t0, t0.elapsed(), 0, 0);
        }
        result
    }

    /// Producer: stage one decoded partition.
    pub(crate) fn push(&self, item: LoadedPartition) {
        let mut g = lock_recover(&self.state);
        g.produced += 1;
        self.obs.produced.inc();
        if !self.cancelled.load(Ordering::Acquire) {
            g.ready.push_back(item);
        }
        if g.produced >= self.total {
            g.done_producing = true;
        }
        self.cv.notify_all();
    }

    /// Producer: record a failed decode; poisons the stream.
    pub(crate) fn fail(&self, message: String) {
        let mut g = lock_recover(&self.state);
        g.failed.get_or_insert(message);
        g.done_producing = true;
        self.cv.notify_all();
    }

    /// Producer: poison the stream as *closed* (graph released, buffer
    /// pool shut) — consumers get a typed
    /// [`PgError::Closed`](crate::coordinator::PgError) from [`next`]
    /// instead of a generic stream failure.
    pub(crate) fn fail_closed(&self, message: String) {
        let mut g = lock_recover(&self.state);
        if g.failed.is_none() {
            g.failed = Some(message);
            g.failed_closed = true;
        }
        g.done_producing = true;
        self.cv.notify_all();
    }

    /// Producer: mark the end of production (used on cancellation exits so
    /// consumers don't wait for partitions that will never arrive).
    pub(crate) fn finish_producing(&self) {
        let mut g = lock_recover(&self.state);
        g.done_producing = true;
        self.cv.notify_all();
    }

    fn cancel(&self) {
        self.cancelled.store(true, Ordering::Release);
        let mut g = lock_recover(&self.state);
        g.ready.clear(); // staged items will never be consumed
        self.cv.notify_all();
    }

    fn next(&self) -> Result<Option<LoadedPartition>> {
        let t0 = std::time::Instant::now();
        let mut g = lock_recover(&self.state);
        let mut stalled = false;
        loop {
            if let Some(e) = &g.failed {
                if g.failed_closed {
                    return Err(crate::coordinator::PgError::Closed(format!(
                        "partition stream failed: {e}"
                    ))
                    .into());
                }
                bail!("partition stream failed: {e}");
            }
            if self.cancelled.load(Ordering::Acquire) {
                return Ok(None);
            }
            if let Some(item) = g.ready.pop_front() {
                g.consumed += 1;
                self.obs.consumed.inc();
                if stalled {
                    self.consumer_stalls.fetch_add(1, Ordering::Relaxed);
                    self.obs.consumer_stalls.inc();
                } else {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    self.obs.prefetch_hits.inc();
                }
                // Wake the producer parked on window space (and fellow
                // consumers racing for remaining items).
                self.cv.notify_all();
                drop(g);
                if stalled {
                    obs::tracer().record("stream", "consumer-stall", t0, t0.elapsed(), 0, 0);
                }
                return Ok(Some(item));
            }
            if g.done_producing {
                return Ok(None);
            }
            stalled = true;
            g = self.cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    fn counters(&self) -> StreamCounters {
        let g = lock_recover(&self.state);
        StreamCounters {
            produced: g.produced as u64,
            consumed: g.consumed as u64,
            prefetch_hits: self.hits.load(Ordering::Relaxed),
            consumer_stalls: self.consumer_stalls.load(Ordering::Relaxed),
            producer_stalls: self.producer_stalls.load(Ordering::Relaxed),
        }
    }
}

/// The consumer handle of a partitioned request — shareable across any
/// number of consumer threads (`&self` everywhere, internally locked).
/// Dropping the stream cancels outstanding production and joins the
/// server's dispatcher thread.
#[derive(Debug)]
pub struct PartitionStream {
    shared: Arc<StreamShared>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl PartitionStream {
    /// Assemble a stream from its shared core and the server's dispatcher
    /// handle (coordinator-internal constructor).
    pub(crate) fn new(
        shared: Arc<StreamShared>,
        dispatcher: std::thread::JoinHandle<()>,
    ) -> Self {
        Self { shared, dispatcher: Some(dispatcher) }
    }

    /// Total partitions this stream will deliver when fully drained.
    pub fn total_parts(&self) -> usize {
        self.shared.total
    }

    /// Pull the next staged partition. Blocks while the producer is
    /// behind; `Ok(None)` once the stream is exhausted or cancelled; `Err`
    /// if any partition failed to decode. Safe to call from many threads —
    /// each partition is handed to exactly one caller (work stealing).
    pub fn next(&self) -> Result<Option<LoadedPartition>> {
        self.shared.next()
    }

    /// Cancel: unscheduled partitions are dropped, staged ones discarded;
    /// consumers see `Ok(None)`, the producer stops at the next window
    /// check.
    pub fn cancel(&self) {
        self.shared.cancel();
    }

    pub fn is_cancelled(&self) -> bool {
        self.shared.cancelled.load(Ordering::Acquire)
    }

    /// Interleaving counters (prefetch hit rate, stalls).
    pub fn counters(&self) -> StreamCounters {
        self.shared.counters()
    }

    /// Drain the whole stream on the calling thread (single-consumer
    /// convenience; tests and oracles).
    pub fn collect_all(&self) -> Result<Vec<LoadedPartition>> {
        let mut out = Vec::new();
        while let Some(p) = self.next()? {
            out.push(p);
        }
        Ok(out)
    }
}

impl Drop for PartitionStream {
    fn drop(&mut self) {
        self.shared.cancel();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::VertexRange;

    fn dummy_partition(index: usize) -> LoadedPartition {
        LoadedPartition {
            part: Partition {
                index,
                vertices: VertexRange::new(0, 2),
                edge_span: (0, 3),
                targets: VertexRange::new(0, 2),
            },
            block: DecodedBlock {
                first_vertex: 0,
                offsets: vec![0, 2, 3],
                edges: vec![1, 0, 1],
            },
        }
    }

    /// Stand-in producer thread for stream-only tests.
    fn spawn_producer(shared: Arc<StreamShared>, total: usize) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            for i in 0..total {
                if !shared.wait_for_window() {
                    break;
                }
                shared.push(dummy_partition(i));
            }
            shared.finish_producing();
        })
    }

    #[test]
    fn two_consumers_drain_every_partition_once() {
        let shared = StreamShared::new(40, 4);
        let stream = PartitionStream::new(Arc::clone(&shared), spawn_producer(shared, 40));
        let seen = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {
                    while let Some(p) = stream.next().expect("next") {
                        seen.lock().unwrap().push(p.part.index);
                    }
                });
            }
        });
        let mut got = seen.into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..40).collect::<Vec<_>>());
        let c = stream.counters();
        assert_eq!(c.produced, 40);
        assert_eq!(c.consumed, 40);
        assert_eq!(c.prefetch_hits + c.consumer_stalls, 40);
    }

    #[test]
    fn window_bounds_staging_depth() {
        let shared = StreamShared::new(10, 2);
        let stream =
            PartitionStream::new(Arc::clone(&shared), spawn_producer(Arc::clone(&shared), 10));
        // Let the producer run ahead: it must stall at 2 staged.
        std::thread::sleep(std::time::Duration::from_millis(30));
        {
            let g = shared.state.lock().unwrap();
            assert!(g.ready.len() <= 2, "staging depth {} exceeds window", g.ready.len());
        }
        let all = stream.collect_all().unwrap();
        assert_eq!(all.len(), 10);
        assert!(stream.counters().producer_stalls >= 1);
    }

    #[test]
    fn cancel_unblocks_everyone() {
        let shared = StreamShared::new(1000, 1);
        let stream =
            PartitionStream::new(Arc::clone(&shared), spawn_producer(Arc::clone(&shared), 1000));
        let _ = stream.next().unwrap();
        stream.cancel();
        // Consumers see exhaustion, not a hang.
        assert!(stream.next().unwrap().is_none());
        assert!(stream.is_cancelled());
    }

    #[test]
    fn failure_poisons_the_stream() {
        let shared = StreamShared::new(5, 2);
        let s2 = Arc::clone(&shared);
        let producer = std::thread::spawn(move || {
            s2.push(dummy_partition(0));
            s2.fail("disk on fire".into());
        });
        let stream = PartitionStream::new(shared, producer);
        // The staged partition may or may not be consumed before the error
        // lands; either way the error must surface, and then stick.
        let mut saw_err = false;
        for _ in 0..3 {
            match stream.next() {
                Err(e) => {
                    assert!(e.to_string().contains("disk on fire"));
                    saw_err = true;
                    break;
                }
                Ok(Some(_)) => continue,
                Ok(None) => break,
            }
        }
        assert!(saw_err, "decode failure must reach consumers");
    }
}
