//! Offline stub of the `xla` (PJRT) crate API surface used by
//! `rust/src/runtime/exec.rs`.
//!
//! The real crate binds the PJRT C API and is unavailable in the offline
//! build. This stub keeps the runtime layer compiling unchanged:
//! [`PjRtClient::cpu`] fails with a recognizable error, so
//! `ArtifactSet::load` degrades exactly like a missing artifacts directory
//! and every caller falls back to the native Rust engines. No stub method
//! past client creation is ever reached.

use std::fmt;

/// Error type; the runtime formats it with `{:?}`.
pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable() -> Error {
    Error("PJRT unavailable: offline xla stub (vendor/xla)".to_string())
}

/// Stub PJRT client. [`PjRtClient::cpu`] always fails.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Stub HLO module handle.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        Err(unavailable())
    }
}

/// Stub computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Mirrors the real `execute` shape: per-device, per-output buffers.
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Stub host literal.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Self {
        Self
    }

    pub fn scalar<T: Copy>(_value: T) -> Self {
        Self
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = format!("{err:?}");
        assert!(msg.contains("offline xla stub"), "got: {msg}");
    }
}
