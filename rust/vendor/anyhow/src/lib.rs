//! Minimal, dependency-free drop-in for the subset of the `anyhow` API this
//! repository uses (`Result`, `Error`, `Context`, `downcast_ref`,
//! `anyhow!`, `bail!`, `ensure!`). The real crate is unavailable in the
//! offline build environment; this keeps the public surface
//! source-compatible.
//!
//! Semantics mirror `anyhow`:
//! * `Error` is a cheap dynamic error carrying a context chain.
//! * `Display` prints the outermost context; `{:#}` prints the whole chain
//!   joined by `": "`; `Debug` prints the chain as a `Caused by:` list.
//! * `Context` attaches context to `Result` and `Option` values.
//! * Typed errors converted via `?`/`From` keep their concrete root, so
//!   `downcast_ref::<T>()` recovers them through any number of context
//!   layers (walking the root's `source()` chain like the real crate).

use std::fmt;

/// `Result` with a defaulted error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Dynamic error: an outermost-first chain of messages, plus — when the
/// error was converted from a typed `std::error::Error` — the boxed root
/// itself so `downcast_ref` can recover the concrete type.
pub struct Error {
    /// `chain[0]` is the outermost context, `chain.last()` the root cause.
    chain: Vec<String>,
    /// The typed root cause, kept for `downcast_ref`; `None` for errors
    /// built from bare messages (`anyhow!`, `Error::msg`).
    root: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Self { chain: vec![message.to_string()], root: None }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause message (innermost).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate the chain from outermost to root.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// Recover the typed root cause (or anything on its `source()` chain),
    /// like `anyhow::Error::downcast_ref`. Context layers added with
    /// `context`/`with_context` are message-only and never mask the root.
    pub fn downcast_ref<T: std::error::Error + 'static>(&self) -> Option<&T> {
        let root = self.root.as_ref()?;
        let mut cur: &(dyn std::error::Error + 'static) = &**root;
        loop {
            if let Some(t) = cur.downcast_ref::<T>() {
                return Some(t);
            }
            cur = cur.source()?;
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain, root: Some(Box::new(e)) }
    }
}

/// Context-attachment extension, like `anyhow::Context`.
pub trait Context<T, E> {
    /// Wrap the error with `context`.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($tt:tt)+) => {
        if !$cond {
            return Err($crate::anyhow!($($tt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err())
            .context("reading header")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading header");
        assert_eq!(format!("{e:#}"), "reading header: disk on fire");
        assert_eq!(e.root_cause(), "disk on fire");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn fails(flag: bool) -> Result<u32> {
            ensure!(!flag, "flag was {flag}");
            if flag {
                bail!("unreachable");
            }
            Ok(9)
        }
        assert_eq!(fails(false).unwrap(), 9);
        assert_eq!(fails(true).unwrap_err().to_string(), "flag was true");
        let e = anyhow!("value {} bad", 3);
        assert_eq!(e.to_string(), "value 3 bad");
    }

    #[test]
    fn downcast_ref_survives_context_layers() {
        let e: Error = Result::<(), _>::Err(io_err())
            .context("reading header")
            .unwrap_err()
            .context("opening graph");
        let io = e.downcast_ref::<std::io::Error>().expect("typed root kept");
        assert_eq!(io.to_string(), "disk on fire");
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        // Message-built errors carry no typed root.
        assert!(anyhow!("plain").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<u32> {
            let n = "not a number".parse::<u32>()?;
            Ok(n)
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "invalid digit found in string");
    }
}
