//! End-to-end observability tests: the per-graph metrics registry fed by
//! real loads, cross-graph snapshot merge + JSON round-trip (the
//! distributed metrics-frame schema), and the always-on tracer's
//! dual-clock Chrome export via `Options::trace_path`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use paragrapher::coordinator::{GraphType, Options, Paragrapher};
use paragrapher::formats::webgraph;
use paragrapher::graph::{generators, CsrGraph};
use paragrapher::obs::{names, MetricsSnapshot};
use paragrapher::storage::{DeviceKind, SimStore};
use paragrapher::util::json::Json;

fn store_with(g: &CsrGraph, base: &str) -> Arc<SimStore> {
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    for (name, data) in webgraph::serialize(g, base) {
        store.put(&name, data);
    }
    store
}

fn open(
    store: &Arc<SimStore>,
    base: &str,
    opts: Options,
) -> paragrapher::coordinator::PgGraph {
    Paragrapher::init()
        .open_graph(Arc::clone(store), base, GraphType::CsxWg400, opts)
        .expect("open graph")
}

#[test]
fn registry_covers_the_request_path() {
    let g = generators::barabasi_albert(3000, 6, 11);
    let store = store_with(&g, "g");
    let graph = open(
        &store,
        "g",
        Options { buffers: 2, buffer_edges: 4000, ..Options::default() },
    );

    // One of each request kind.
    let block = graph.load_whole_graph().expect("load");
    assert_eq!(block.num_edges(), g.num_edges());
    for v in [0usize, 17, 1234] {
        let _ = graph.successors(v).expect("successors");
    }
    let stream = graph.csx_get_partitions(4).expect("partitions");
    let edges = AtomicU64::new(0);
    paragrapher::algorithms::partitioned::for_each_partition(&stream, 2, |p| {
        edges.fetch_add(p.num_edges(), Ordering::Relaxed);
        Ok(())
    })
    .expect("drain stream");
    assert_eq!(edges.load(Ordering::Relaxed), g.num_edges());

    let snap = graph.metrics_snapshot();
    // Request-kind latency histograms.
    assert_eq!(snap.hists[names::REQ_CSX].total, 1, "one whole-graph csx request");
    assert_eq!(snap.hists[names::REQ_SUCCESSORS].total, 3);
    assert_eq!(snap.hists[names::REQ_PARTITION].total, 4);
    assert!(snap.hists[names::BUFFER_CLAIM_WAIT].total >= 1, "buffer claims recorded");
    // Decode histograms: both clocks see the same blocks.
    let real = &snap.hists[names::DECODE_BLOCK_REAL];
    let virt = &snap.hists[names::DECODE_BLOCK_VIRT];
    assert!(real.total >= 1, "block decodes recorded");
    assert_eq!(real.total, virt.total, "dual clocks record the same blocks");
    // Counter mirrors: the stream counters surface under registry names…
    assert_eq!(snap.counters[names::STREAM_PRODUCED], 4);
    assert_eq!(snap.counters[names::STREAM_CONSUMED], 4);
    assert!(snap.counters.contains_key(names::CACHE_HITS));
    // …and the legacy GraphStats fields are views over the same registry.
    assert_eq!(
        snap.counters["graph.blocks_decoded"],
        graph.stats().blocks_decoded.load(Ordering::Relaxed)
    );
    assert!(snap.counters["graph.blocks_decoded"] >= 1);
    // Whole-graph load decoded every edge once; the partition drain
    // decoded them again.
    assert!(snap.counters["graph.edges_decoded"] >= 2 * g.num_edges());
}

#[test]
fn snapshots_merge_across_graphs_and_round_trip() {
    let g = generators::barabasi_albert(2000, 5, 7);
    let store = store_with(&g, "g");
    let a = open(&store, "g", Options::default());
    let b = open(&store, "g", Options::default());
    a.load_whole_graph().expect("load a");
    b.load_whole_graph().expect("load b");
    let sa = a.metrics_snapshot();
    let sb = b.metrics_snapshot();
    // Registries are per-graph: each saw exactly its own request.
    assert_eq!(sa.hists[names::REQ_CSX].total, 1);
    assert_eq!(sb.hists[names::REQ_CSX].total, 1);
    let mut merged = sa.clone();
    merged.merge(&sb);
    assert_eq!(merged.hists[names::REQ_CSX].total, 2);
    assert_eq!(
        merged.counters["graph.edges_decoded"],
        sa.counters["graph.edges_decoded"] + sb.counters["graph.edges_decoded"]
    );
    // The wire schema round-trips exactly (the distributed metrics frame
    // and the ci-summary --json payload share it).
    let back = MetricsSnapshot::from_json(&merged.to_json()).expect("parse snapshot");
    assert_eq!(back, merged);
}

#[test]
fn trace_path_exports_dual_clock_chrome_trace_on_release() {
    let dir = std::env::temp_dir().join(format!("pg_obs_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("trace dir");
    let path = dir.join("trace.json");

    let g = generators::barabasi_albert(2500, 6, 13);
    let store = store_with(&g, "g");
    let pg = Paragrapher::init();
    let graph = pg
        .open_graph(
            Arc::clone(&store),
            "g",
            GraphType::CsxWg400,
            Options {
                trace_path: Some(path.clone()),
                buffer_edges: 3000,
                ..Options::default()
            },
        )
        .expect("open graph");
    let block = graph.load_whole_graph().expect("load");
    assert_eq!(block.num_edges(), g.num_edges());
    let _ = graph.successors(42).expect("successors");
    pg.release_graph(graph); // exports to trace_path

    let text = std::fs::read_to_string(&path).expect("trace file written on release");
    let doc = Json::parse(&text).expect("trace is valid JSON");
    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
    let complete: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .collect();
    let cats: std::collections::BTreeSet<&str> =
        complete.iter().filter_map(|e| e.get("cat").and_then(Json::as_str)).collect();
    for want in ["request", "buffer", "decode", "delivery"] {
        assert!(cats.contains(want), "missing span category {want:?} in {cats:?}");
    }
    let pids: std::collections::BTreeSet<u64> =
        complete.iter().filter_map(|e| e.get("pid").and_then(Json::as_u64)).collect();
    assert!(pids.contains(&1), "real-clock lane missing: {pids:?}");
    assert!(pids.contains(&2), "virtual-clock lane missing: {pids:?}");
    std::fs::remove_dir_all(&dir).ok();
}
