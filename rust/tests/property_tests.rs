//! Property-based tests (in-repo harness: seeded Xoshiro case generation;
//! proptest is unavailable offline). Each property runs over a sweep of
//! random cases; failures print the offending seed for reproduction.

use paragrapher::formats::webgraph::{self, WgParams};
use paragrapher::formats::{FormatKind, GraphSource, SourceConfig, WebGraphSource};
use paragrapher::graph::{CsrGraph, VertexId};
use paragrapher::storage::sim::ReadCtx;
use paragrapher::storage::{DeviceKind, IoAccount, SimStore};
use paragrapher::util::rng::Xoshiro256;

/// Random graph with `n` vertices and up to `m` edges (may include
/// isolated vertices, hubs, empty graphs).
fn random_graph(rng: &mut Xoshiro256, max_n: usize, max_m: usize) -> CsrGraph {
    let n = 1 + rng.next_below(max_n as u64) as usize;
    let m = rng.next_below(max_m as u64 + 1) as usize;
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let s = rng.next_below(n as u64) as VertexId;
        let d = rng.next_below(n as u64) as VertexId;
        edges.push((s, d));
    }
    edges.sort_unstable();
    edges.dedup();
    CsrGraph::from_edges(n, &edges)
}

fn random_params(rng: &mut Xoshiro256) -> WgParams {
    WgParams {
        window: rng.next_below(16) as u32,
        max_ref_chain: rng.next_below(8) as u32,
        zeta_k: 1 + rng.next_below(6) as u32,
        min_interval_len: 2 + rng.next_below(8) as u32,
    }
}

#[test]
fn prop_webgraph_compress_decompress_identity() {
    let mut rng = Xoshiro256::seed_from_u64(0xC0FFEE);
    for case in 0..40 {
        let mut crng = rng.split();
        let g = random_graph(&mut crng, 400, 6000);
        let params = random_params(&mut crng);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in webgraph::serialize_with(&g, "p", params) {
            store.put(&name, data);
        }
        let accounts: Vec<IoAccount> = (0..3).map(|_| IoAccount::new()).collect();
        let loaded = FormatKind::WebGraph
            .load_full(&store, "p", ReadCtx::default(), &accounts)
            .unwrap_or_else(|e| panic!("case {case} ({params:?}): {e}"));
        assert_eq!(loaded, g, "case {case} params {params:?}");
    }
}

#[test]
fn prop_all_formats_roundtrip_random_graphs() {
    let mut rng = Xoshiro256::seed_from_u64(0xBEEF);
    for case in 0..15 {
        let mut crng = rng.split();
        let g = random_graph(&mut crng, 200, 3000);
        let store = SimStore::new(DeviceKind::Dram);
        for fk in FormatKind::ALL {
            let base = format!("c{case}-{fk:?}");
            fk.write_to_store(&g, &store, &base);
            let accounts: Vec<IoAccount> = (0..2).map(|_| IoAccount::new()).collect();
            let loaded = fk
                .load_full(&store, &base, ReadCtx::default(), &accounts)
                .unwrap_or_else(|e| panic!("case {case} {fk:?}: {e}"));
            assert_eq!(loaded, g, "case {case} {fk:?}");
        }
    }
}

#[test]
fn prop_successors_agree_with_decode_range() {
    // For every vertex of random graphs under random coding parameters and
    // random cache geometries, the random-access path must return exactly
    // the row that range decoding produces (and both must match the
    // original graph).
    let mut rng = Xoshiro256::seed_from_u64(0x5EED);
    for case in 0..12 {
        let mut crng = rng.split();
        let g = random_graph(&mut crng, 300, 4000);
        let params = random_params(&mut crng);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in webgraph::serialize_with(&g, "p", params) {
            store.put(&name, data);
        }
        let cfg = SourceConfig {
            block_vertices: 1 + crng.next_below(96) as usize,
            cache_cost: crng.next_below(1 << 16),
            ..SourceConfig::default()
        };
        let src = WebGraphSource::open(&store, "p", cfg)
            .unwrap_or_else(|e| panic!("case {case} ({params:?}): {e}"));
        let n = g.num_vertices();
        let block = src
            .decode_range(0, n)
            .unwrap_or_else(|e| panic!("case {case} ({params:?}): {e}"));
        for v in 0..n {
            let succ = src
                .successors(v)
                .unwrap_or_else(|e| panic!("case {case} vertex {v}: {e}"));
            assert_eq!(succ, block.neighbors(v), "case {case} vertex {v} ({cfg:?})");
            assert_eq!(succ, g.neighbors(v as VertexId), "case {case} vertex {v}");
        }
    }
}

#[test]
fn prop_random_access_roundtrip_at_max_ref_chain_depth() {
    // Encode a reference-heavy graph with tight chain bounds and verify the
    // encoder actually builds chains of the configured depth AND that
    // per-vertex random access (which must resolve those chains) round-trips
    // every list. Guards the bound logic on both sides of the codec.
    let g = paragrapher::graph::generators::similarity_blocks(600, 48, 16, 3);
    for max_ref_chain in [1u32, 2, 3] {
        let params = WgParams { window: 7, max_ref_chain, ..WgParams::default() };
        let (_stream, _offsets, stats) = webgraph::compress(&g, params);
        assert_eq!(
            stats.max_ref_chain_depth, max_ref_chain,
            "dense similarity graph must exercise the full chain budget"
        );
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in webgraph::serialize_with(&g, "c", params) {
            store.put(&name, data);
        }
        // block_vertices = 1: every access is a single-vertex random decode,
        // so every reference resolves through the bounded recursion.
        let cfg = SourceConfig { block_vertices: 1, ..SourceConfig::default() };
        let src = WebGraphSource::open(&store, "c", cfg).unwrap();
        for v in 0..g.num_vertices() {
            assert_eq!(
                src.successors(v).unwrap(),
                g.neighbors(v as VertexId),
                "chain={max_ref_chain} vertex {v}"
            );
        }
    }
}

#[test]
fn prop_any_partition_of_requests_delivers_same_edges() {
    use paragrapher::coordinator::{GraphType, Options, Paragrapher, VertexRange};
    use std::sync::{Arc, Mutex};

    let mut rng = Xoshiro256::seed_from_u64(0xF00D);
    let mut crng = rng.split();
    let g = random_graph(&mut crng, 600, 8000);
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    FormatKind::WebGraph.write_to_store(&g, &store, "g");
    let pg = Paragrapher::init();
    for case in 0..8 {
        // Random partition of [0, n) into consecutive ranges.
        let n = g.num_vertices();
        let mut cuts = vec![0usize, n];
        for _ in 0..crng.next_below(6) {
            cuts.push(crng.next_below(n as u64 + 1) as usize);
        }
        cuts.sort_unstable();
        cuts.dedup();
        let graph = pg
            .open_graph(
                Arc::clone(&store),
                "g",
                GraphType::CsxWg400,
                Options {
                    buffers: 1 + crng.next_below(4) as usize,
                    buffer_edges: 1 + crng.next_below(5000),
                    ..Options::default()
                },
            )
            .expect("open");
        let collected: Arc<Mutex<Vec<(VertexId, VertexId)>>> =
            Arc::new(Mutex::new(Vec::new()));
        for w in cuts.windows(2) {
            let c2 = Arc::clone(&collected);
            let req = graph
                .csx_get_subgraph(
                    VertexRange::new(w[0], w[1]),
                    Arc::new(move |blk| c2.lock().unwrap().extend(blk.iter_edges())),
                )
                .expect("request");
            req.wait();
            assert!(!req.is_failed(), "case {case}: {:?}", req.error());
        }
        let mut got = collected.lock().unwrap().clone();
        got.sort_unstable();
        let mut expected: Vec<(VertexId, VertexId)> = g.iter_edges().collect();
        expected.sort_unstable();
        assert_eq!(got, expected, "case {case} cuts {cuts:?}");
    }
}

#[test]
fn prop_decoder_never_panics_on_corrupted_streams() {
    let mut rng = Xoshiro256::seed_from_u64(0xDEAD);
    for case in 0..25 {
        let mut crng = rng.split();
        let g = random_graph(&mut crng, 150, 1500);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, mut data) in webgraph::serialize(&g, "g") {
            // Corrupt the graph stream (flip random bytes), keep sidecars.
            if name.ends_with(".graph") && !data.is_empty() {
                for _ in 0..1 + crng.next_below(16) {
                    let idx = crng.next_below(data.len() as u64) as usize;
                    data[idx] ^= (1 + crng.next_below(255)) as u8;
                }
            }
            store.put(&name, data);
        }
        let accounts: Vec<IoAccount> = (0..2).map(|_| IoAccount::new()).collect();
        // Either Ok (corruption happened to decode consistently) or Err —
        // never a panic. `load_full` panics internally on decode_range
        // expect… so call the decoder directly.
        let acct = &accounts[0];
        let Ok(meta) = webgraph::read_meta(&store, "g", ReadCtx::default(), acct) else {
            continue;
        };
        let Ok(offs) = webgraph::read_offsets(&store, "g", ReadCtx::default(), acct) else {
            continue;
        };
        let Ok(dec) =
            webgraph::Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), acct)
        else {
            continue;
        };
        let _ = dec.decode_range(0, meta.num_vertices, acct);
        let _ = dec.decode_vertex(crng.next_below(meta.num_vertices.max(1) as u64) as usize, acct);
        let _ = case;
    }
}

#[test]
fn prop_jtcc_invariant_under_partitioning_and_order() {
    use paragrapher::algorithms::{bfs::wcc_by_bfs, count_components, jtcc::JtUnionFind};
    let mut rng = Xoshiro256::seed_from_u64(0xAB);
    for case in 0..10 {
        let mut crng = rng.split();
        let g = random_graph(&mut crng, 300, 2500);
        let truth = count_components(&wcc_by_bfs(&g));
        let mut edges: Vec<(VertexId, VertexId)> = g.iter_edges().collect();
        crng.shuffle(&mut edges);
        let uf = JtUnionFind::new(g.num_vertices(), crng.next_u64());
        for (s, d) in edges {
            uf.union(s, d);
        }
        assert_eq!(uf.count_components(), truth, "case {case}");
    }
}
