//! Integration tests of the multi-tenant serving front-end: graph churn
//! under in-flight partition streams, fault isolation between tenants on
//! different graphs, and per-tenant decoded-cache quotas — all through the
//! public `GraphServer` surface.

use std::sync::Arc;
use std::time::{Duration, Instant};

use paragrapher::coordinator::{GraphType, Options, PgError};
use paragrapher::formats::webgraph;
use paragrapher::graph::generators;
use paragrapher::obs::names;
use paragrapher::serve::{GraphServer, ServeReply, ServeRequest, ServerOptions, TenantQuotas};
use paragrapher::storage::{DeviceKind, FaultPlan, SimStore};

/// A server with one seeded BA graph per `(name, vertices, seed)` entry,
/// small buffers and a pinned two-deep prefetch window so partition
/// streams are provably mid-flight when churn hits.
fn open_server(graphs: &[(&str, usize, u64)]) -> GraphServer {
    let server = GraphServer::new(ServerOptions::default());
    for &(name, n, seed) in graphs {
        let g = generators::barabasi_albert(n, 8, seed);
        let store = Arc::new(SimStore::new(DeviceKind::Dram));
        for (file, data) in webgraph::serialize(&g, name) {
            store.put(&file, data);
        }
        let opts =
            Options { buffers: 2, buffer_edges: 4096, prefetch_window: 2, ..Options::default() };
        server.open_store(name, store, name, GraphType::CsxWg400, opts).expect("open graph");
    }
    server
}

fn p99_ms(samples: &[f64]) -> f64 {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    s[((s.len() - 1) as f64 * 0.99).round() as usize]
}

/// Satellite: reopening a graph while two tenants hold in-flight
/// `PartitionStream`s must poison those streams into a typed
/// [`PgError::Closed`] — never a hang, never a truncated drain that reads
/// as complete — and every claimed buffer must come back.
#[test]
fn reopen_poisons_in_flight_partition_streams_typed() {
    let server = open_server(&[("g", 6000, 11)]);
    server.register_tenant("t1", TenantQuotas::default()).expect("register t1");
    server.register_tenant("t2", TenantQuotas::default()).expect("register t2");
    let old = server.graph("g").expect("open graph handle");
    let buffers = old.options().buffers;

    // Two tenants hold mid-flight streams: one partition consumed each,
    // the producers parked on the two-deep staging window.
    let s1 = old.csx_get_partitions(64).expect("stream 1");
    let s2 = old.csx_get_partitions(64).expect("stream 2");
    assert!(s1.next().expect("first partition").is_some());
    assert!(s2.next().expect("first partition").is_some());

    server.reopen("g").expect("reopen under traffic");

    for s in [&s1, &s2] {
        let err = loop {
            match s.next() {
                Ok(Some(_)) => continue, // staged before the close: fine
                Ok(None) => panic!("stream read as complete despite churn"),
                Err(e) => break e,
            }
        };
        assert!(
            matches!(err.downcast_ref::<PgError>(), Some(PgError::Closed(_))),
            "want PgError::Closed, got: {err:#}"
        );
    }
    drop(s1);
    drop(s2);

    // Zero leaked buffers on the closed handle: queued decode jobs drain
    // and recycle even against a closed pool.
    let deadline = Instant::now() + Duration::from_secs(10);
    while old.idle_buffers() != buffers && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(old.idle_buffers(), buffers, "buffer leak on the closed handle");

    // The fresh epoch serves both tenants, pool whole.
    let fresh = server.graph("g").expect("reopened graph handle");
    assert!(!Arc::ptr_eq(&old, &fresh), "reopen must install a fresh handle");
    for t in ["t1", "t2"] {
        match server.call(t, "g", ServeRequest::Successors { vertex: 17 }).expect("serve") {
            ServeReply::Successors(_) => {}
            other => panic!("unexpected reply: {other:?}"),
        }
    }
    assert_eq!(fresh.idle_buffers(), fresh.options().buffers);
}

/// Satellite: a PR-9 fault plan installed under one graph degrades only
/// that graph's tenants. The victim sees typed `Faulted` (and its blocks
/// quarantine); the healthy tenant on the other graph keeps succeeding
/// with p99 within 2x its clean baseline.
#[test]
fn fault_plan_through_serve_isolates_tenants() {
    let server = open_server(&[("ga", 4000, 21), ("gb", 4000, 22)]);
    server.register_tenant("healthy", TenantQuotas::default()).expect("register healthy");
    server.register_tenant("victim", TenantQuotas::default()).expect("register victim");

    let healthy_call = |i: usize| -> f64 {
        let v = (i * 61) % 4000;
        let t0 = Instant::now();
        server
            .call("healthy", "ga", ServeRequest::Successors { vertex: v })
            .expect("healthy tenant request failed");
        t0.elapsed().as_secs_f64() * 1e3
    };

    let clean: Vec<f64> = (0..60).map(healthy_call).collect();

    // Every read of gb's stream now faults persistently.
    let gb = server.graph("gb").expect("gb open");
    let plan = FaultPlan::parse("eio:*.graph@count=inf", 7).expect("fault spec");
    gb.store().set_fault_plan(Some(Arc::new(plan)));

    let mut contended = Vec::new();
    let mut typed_faults = 0usize;
    for i in 0..60 {
        // Victim request against the faulted graph: must fail *typed*
        // through the whole serve stack (distinct vertices so the decoded
        // cache cannot mask the fault).
        let v = (i * 67) % 4000;
        let err = server
            .call("victim", "gb", ServeRequest::Successors { vertex: v })
            .expect_err("persistent EIO cannot succeed");
        match err.downcast_ref::<PgError>() {
            Some(PgError::Faulted(_)) => typed_faults += 1,
            Some(PgError::Closed(_)) | Some(PgError::Corrupt(_)) => {}
            other => panic!("untyped failure through the serve layer: {other:?} / {err:#}"),
        }
        contended.push(healthy_call(i));
    }
    assert!(typed_faults > 0, "no PgError::Faulted surfaced to the victim");
    assert!(gb.quarantined_blocks() >= 1, "faulted blocks never quarantined through serve");

    // Fault isolation: the healthy tenant's tail is bounded by its clean
    // baseline (2x + a small absolute slack for CI timer noise).
    let limit = p99_ms(&clean) * 2.0 + 25.0;
    let got = p99_ms(&contended);
    assert!(got <= limit, "healthy p99 {got:.3}ms exceeds limit {limit:.3}ms");

    // Recovery: lift the plan and the quarantine; the victim serves again.
    gb.store().set_fault_plan(None);
    gb.clear_quarantine();
    server.call("victim", "gb", ServeRequest::Successors { vertex: 33 }).expect("post-recovery");
}

/// Satellite: a tenant's decoded-cache residency stays under its quota
/// (its own LRU entries evict first) and the per-tenant
/// `cache.decoded.{hits,evictions}.<tenant>` counters land in the graph's
/// metrics registry.
#[test]
fn cache_quota_bounds_residency_with_labeled_counters() {
    let server = open_server(&[("g", 4000, 31)]);
    let quota = 2000u64;
    let quotas = TenantQuotas { cache_quota_cost: quota, ..TenantQuotas::default() };
    server.register_tenant("small", quotas).expect("register small");
    let graph = server.graph("g").expect("open graph handle");
    // Re-registering returns the same tag the serve layer bills against.
    let tag = graph.register_cache_tenant("small", quota);

    // Touch many distinct 64-vertex source blocks (each ~64 + 8*64 cost
    // units) — far more than the quota admits resident at once.
    for i in 0..120usize {
        let v = (i * 64 + 1) % 4000;
        server.call("small", "g", ServeRequest::Successors { vertex: v }).expect("serve");
    }
    // Re-touch one hot vertex: one re-decode, then counted hits.
    for _ in 0..4 {
        server.call("small", "g", ServeRequest::Successors { vertex: 65 }).expect("serve");
    }

    let resident = graph.cache_tenant_resident(tag);
    assert!(resident <= quota, "tenant resident {resident} exceeds quota {quota}");

    let snap = graph.metrics_snapshot();
    let hits_key = names::cache_tenant_hits("small");
    let evix_key = names::cache_tenant_evictions("small");
    let hits = snap.counters.get(hits_key.as_str()).copied().unwrap_or(0);
    let evix = snap.counters.get(evix_key.as_str()).copied().unwrap_or(0);
    assert!(hits >= 1, "no per-tenant cache hit recorded");
    assert!(evix >= 1, "quota never evicted despite oversubscription");
}
