#!/usr/bin/env python3
"""Generator for the golden WebGraph fixtures (tiny.graph / tiny.offsets /
tiny.properties).

This is a line-by-line port of the Rust encoder (`formats/webgraph/encode.rs`)
and serializer (`formats/webgraph/mod.rs::serialize_with`) for the fixed tiny
graph in `golden_format_tests.rs`. The fixture bytes are checked in; the test
re-encodes the same graph and byte-compares, so any silent format drift —
which would invalidate cross-PR benchmark comparisons — fails CI.

Run from the repo root:  python3 rust/tests/golden/gen_golden.py
It also runs a port of the decoder and asserts the fixture round-trips.
"""

import os

# ---- WgParams::default() ----
WINDOW = 7
MAX_REF_CHAIN = 3
ZETA_K = 3
MIN_INTERVAL_LEN = 3

# ---- The tiny graph (keep in sync with golden_format_tests.rs) ----
ADJ = [
    [1, 2, 3, 4],            # 0: one interval
    [0, 2, 4, 6],            # 1: residuals only
    [1, 3, 4],               # 2: partial copy of a window vertex
    [5],                     # 3: single residual
    [],                      # 4: empty list (degree-0 record)
    [0, 2, 3, 4, 7],         # 5: interval + residuals
    [0, 2, 3, 4, 7],         # 6: identical to 5 -> whole-list reference
    [0, 1, 2, 3, 4, 5, 6],   # 7: one long interval
]
N = len(ADJ)
M = sum(len(a) for a in ADJ)


class BitWriter:
    def __init__(self):
        self.bits = []

    def write_bits(self, value, n):
        value &= (1 << n) - 1 if n < 64 else (1 << 64) - 1
        for i in range(n - 1, -1, -1):
            self.bits.append((value >> i) & 1)

    def write_unary(self, n):
        self.bits.extend([0] * n)
        self.bits.append(1)

    def bit_len(self):
        return len(self.bits)

    def into_bytes(self):
        out = bytearray()
        for i in range(0, len(self.bits), 8):
            chunk = self.bits[i:i + 8]
            b = 0
            for k, bit in enumerate(chunk):
                b |= bit << (7 - k)
            out.append(b)
        return bytes(out)


def bit_width(x):
    return x.bit_length()


def int_to_nat(v):
    return (v << 1) if v >= 0 else ((-v) << 1) - 1


def write_gamma(w, x):
    x1 = x + 1
    width = bit_width(x1)
    w.write_unary(width - 1)
    if width > 1:
        w.write_bits(x1, width - 1)


def gamma_len(x):
    return 2 * bit_width(x + 1) - 1


def write_minimal_binary(w, x, maxv, _bits_hint):
    if maxv <= 1:
        return
    bits = max(bit_width(maxv - 1), 1)
    threshold = (1 << bits) - maxv
    if x < threshold:
        w.write_bits(x, bits - 1)
    else:
        w.write_bits(x + threshold, bits)


def write_zeta(w, x, k):
    x1 = x + 1
    msb = bit_width(x1) - 1
    h = msb // k
    w.write_unary(h)
    left = 1 << (h * k)
    maxv = (left << k) - left
    write_minimal_binary(w, x1 - left, maxv, h * k + k)


def zeta_len(x, k):
    w = BitWriter()
    write_zeta(w, x, k)
    return w.bit_len()


class EncodedAdj:
    def __init__(self):
        self.blocks = []
        self.has_reference = False
        self.intervals = []
        self.residual_list = []
        self.vertex = 0
        self.bits = 0

    def write(self, w):
        if self.has_reference:
            write_gamma(w, len(self.blocks))
            for i, b in enumerate(self.blocks):
                write_gamma(w, b if i == 0 else b - 1)
        write_gamma(w, len(self.intervals))
        prev_right = self.vertex
        for i, (left, length) in enumerate(self.intervals):
            if i == 0:
                write_gamma(w, int_to_nat(left - self.vertex))
            else:
                write_gamma(w, left - prev_right - 2)
            write_gamma(w, length - MIN_INTERVAL_LEN)
            prev_right = left + length - 1
        prev = -1
        for i, res in enumerate(self.residual_list):
            if i == 0:
                write_zeta(w, int_to_nat(res - self.vertex), ZETA_K)
            else:
                write_zeta(w, res - prev - 1, ZETA_K)
            prev = res


def encode_adjacency(vertex, lst, ref_list):
    has_reference = len(ref_list) > 0
    enc = EncodedAdj()
    enc.vertex = vertex
    enc.has_reference = has_reference

    copied_mask = [False] * len(ref_list)
    copied = []
    if has_reference:
        i = 0
        for j, r in enumerate(ref_list):
            while i < len(lst) and lst[i] < r:
                i += 1
            if i < len(lst) and lst[i] == r:
                copied_mask[j] = True
                copied.append(r)
                i += 1
    blocks = []
    if has_reference:
        run_is_copy = True
        run_len = 0
        for c in copied_mask:
            if c == run_is_copy:
                run_len += 1
            else:
                blocks.append(run_len)
                run_is_copy = not run_is_copy
                run_len = 1
        blocks.append(run_len)
        blocks.pop()  # trailing run is implicit
    enc.blocks = blocks

    rest = []
    ci = 0
    for x in lst:
        if ci < len(copied) and copied[ci] == x:
            ci += 1
        else:
            rest.append(x)

    min_len = max(MIN_INTERVAL_LEN, 2)
    intervals = []
    residual_list = []
    i = 0
    while i < len(rest):
        j = i + 1
        while j < len(rest) and rest[j] == rest[j - 1] + 1:
            j += 1
        if j - i >= min_len:
            intervals.append((rest[i], j - i))
        else:
            residual_list.extend(rest[i:j])
        i = j
    enc.intervals = intervals
    enc.residual_list = residual_list

    bits = 0
    if has_reference:
        bits += gamma_len(len(blocks))
        for i, b in enumerate(blocks):
            bits += gamma_len(b if i == 0 else b - 1)
    bits += gamma_len(len(intervals))
    prev_right = vertex
    for i, (left, length) in enumerate(intervals):
        if i == 0:
            bits += gamma_len(int_to_nat(left - vertex))
        else:
            bits += gamma_len(left - prev_right - 2)
        bits += gamma_len(length - MIN_INTERVAL_LEN)
        prev_right = left + length - 1
    prev = -1
    for i, res in enumerate(residual_list):
        if i == 0:
            bits += zeta_len(int_to_nat(res - vertex), ZETA_K)
        else:
            bits += zeta_len(res - prev - 1, ZETA_K)
        prev = res
    enc.bits = bits
    enc.copied = len(copied)
    return enc


def compress():
    w = BitWriter()
    bit_offsets = []
    chain_depth = [0] * N
    for v in range(N):
        bit_offsets.append(w.bit_len())
        lst = ADJ[v]
        write_gamma(w, len(lst))
        if not lst:
            continue
        best = None  # (r, enc)
        no_ref = encode_adjacency(v, lst, [])
        for r in range(1, min(WINDOW, v) + 1):
            u = v - r
            if chain_depth[u] + 1 > MAX_REF_CHAIN:
                continue
            ref_list = ADJ[u]
            if not ref_list:
                continue
            enc = encode_adjacency(v, lst, ref_list)
            if best is None or enc.bits < best[1].bits:
                best = (r, enc)
        use_ref = best is not None and best[1].bits < no_ref.bits
        if use_ref:
            r, enc = best
            chain_depth[v] = chain_depth[v - r] + 1
        else:
            r, enc = 0, no_ref
        write_gamma(w, r)
        enc.write(w)
    bit_offsets.append(w.bit_len())
    return w.into_bytes(), bit_offsets


def serialize():
    stream, bit_offsets = compress()
    total_bits = bit_offsets[-1]

    offsets = bytearray()
    offsets += b"WGOFF2\xF0\xFF"  # OFFSETS_MAGIC_V2
    offsets += N.to_bytes(8, "little")
    offsets += M.to_bytes(8, "little")
    offsets += total_bits.to_bytes(8, "little")
    w = BitWriter()
    prev = 0
    for b in bit_offsets:
        write_gamma(w, b - prev)
        prev = b
    edge_offsets = [0]
    for a in ADJ:
        edge_offsets.append(edge_offsets[-1] + len(a))
    prev = 0
    for e in edge_offsets:
        write_gamma(w, e - prev)
        prev = e
    offsets += w.into_bytes()

    properties = (
        f"version=1\nnodes={N}\narcs={M}\nwindow={WINDOW}\n"
        f"maxrefchain={MAX_REF_CHAIN}\nzetak={ZETA_K}\n"
        f"minintervallength={MIN_INTERVAL_LEN}\nweighted=false\n"
    ).encode()
    return bytes(stream), bytes(offsets), properties


# ---- decoder port (sanity: fixture must round-trip) ----
class BitReader:
    def __init__(self, data, bitpos=0):
        self.data = data
        self.pos = bitpos

    def read_bit(self):
        byte = self.data[self.pos // 8]
        bit = (byte >> (7 - self.pos % 8)) & 1
        self.pos += 1
        return bit

    def read_bits(self, n):
        v = 0
        for _ in range(n):
            v = (v << 1) | self.read_bit()
        return v

    def read_unary(self):
        c = 0
        while self.read_bit() == 0:
            c += 1
        return c


def read_gamma(r):
    width = r.read_unary() + 1
    if width == 1:
        return 0
    return ((1 << (width - 1)) | r.read_bits(width - 1)) - 1


def read_minimal_binary(r, maxv):
    if maxv <= 1:
        return 0
    bits = max(bit_width(maxv - 1), 1)
    threshold = (1 << bits) - maxv
    hi = r.read_bits(bits - 1)
    if hi < threshold:
        return hi
    low = r.read_bits(1)
    return ((hi << 1) | low) - threshold


def read_zeta(r, k):
    h = r.read_unary()
    left = 1 << (h * k)
    maxv = (left << k) - left
    return left + read_minimal_binary(r, maxv) - 1


def nat_to_int(n):
    return (n >> 1) if n % 2 == 0 else -((n + 1) >> 1)


def decode_vertex(stream, bit_offsets, v):
    r = BitReader(stream, bit_offsets[v])
    degree = read_gamma(r)
    if degree == 0:
        return []
    reference = read_gamma(r)
    copied = []
    if reference > 0:
        ref_list = decode_vertex(stream, bit_offsets, v - reference)
        block_count = read_gamma(r)
        blocks = []
        for i in range(block_count):
            raw = read_gamma(r)
            blocks.append(raw if i == 0 else raw + 1)
        pos = 0
        is_copy = True
        for length in blocks:
            if is_copy:
                copied.extend(ref_list[pos:pos + length])
            pos += length
            is_copy = not is_copy
        if is_copy and pos < len(ref_list):
            copied.extend(ref_list[pos:])
    interval_count = read_gamma(r)
    intervals = []
    prev_right = v
    for i in range(interval_count):
        if i == 0:
            left = v + nat_to_int(read_gamma(r))
        else:
            left = prev_right + 2 + read_gamma(r)
        length = read_gamma(r) + MIN_INTERVAL_LEN
        intervals.extend(range(left, left + length))
        prev_right = left + length - 1
    residuals = []
    count = degree - len(copied) - len(intervals)
    prev = None
    for i in range(count):
        if i == 0:
            prev = v + nat_to_int(read_zeta(r, ZETA_K))
        else:
            prev = prev + 1 + read_zeta(r, ZETA_K)
        residuals.append(prev)
    out = sorted(copied + intervals + residuals)
    assert len(out) == degree, f"vertex {v}: degree mismatch"
    return out


def main():
    stream, offsets, properties = serialize()
    # Round-trip sanity before writing anything.
    _, bit_offsets = compress()
    for v in range(N):
        got = decode_vertex(stream, bit_offsets, v)
        assert got == ADJ[v], f"vertex {v}: {got} != {ADJ[v]}"
    here = os.path.dirname(os.path.abspath(__file__))
    for name, data in [
        ("tiny.graph", stream),
        ("tiny.offsets", offsets),
        ("tiny.properties", properties),
    ]:
        with open(os.path.join(here, name), "wb") as f:
            f.write(data)
        print(f"{name}: {len(data)} bytes: {data.hex()}")
    print("round-trip OK")


if __name__ == "__main__":
    main()
