//! Multi-process distributed harness tests: real worker processes
//! (spawned from the `paragrapher` binary via `CARGO_BIN_EXE`), plan
//! shipping over the socket transport, deterministic fault injection,
//! and the PR's regression tests — truncated weights sidecar, poisoned
//! coordinator locks, stale-plan admission.

use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use paragrapher::coordinator::{GraphType, Options, Paragrapher};
use paragrapher::distributed::{oracle_tile_summaries, run_leader, LeaderConfig};
use paragrapher::formats::webgraph;
use paragrapher::graph::generators;
use paragrapher::graph::CsrGraph;
use paragrapher::partition::PartitionPlan;
use paragrapher::storage::{DeviceKind, SimStore};

/// Run `f` on a helper thread; panic (failing the test) if it does not
/// finish under `timeout` — the deadlock/hang detector every fault test
/// runs under ("never hang" is part of the contract being tested).
fn with_watchdog<T: Send + 'static>(
    timeout: Duration,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        let out = f();
        let _ = tx.send(());
        out
    });
    match rx.recv_timeout(timeout) {
        Ok(()) => handle.join().expect("test body panicked"),
        Err(_) => panic!("watchdog: distributed run did not finish within {timeout:?}"),
    }
}

/// Write `g` as an on-disk WebGraph fixture every process opens
/// independently; returns the directory.
fn write_graph_dir(g: &CsrGraph, base: &str, tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("pg_dist_test_{}_{}", tag, std::process::id()));
    std::fs::create_dir_all(&dir).expect("create test dir");
    for (name, data) in webgraph::serialize(g, base) {
        std::fs::write(dir.join(&name), &data).expect("write fixture");
    }
    dir
}

/// A leader config over `dir` that spawns workers from the real
/// `paragrapher` binary Cargo built for this test run.
fn leader_cfg(dir: &std::path::Path) -> LeaderConfig {
    LeaderConfig::new(
        dir,
        "g",
        GraphType::CsxWg400,
        DeviceKind::Ssd,
        vec![env!("CARGO_BIN_EXE_paragrapher").to_string(), "worker".to_string()],
    )
}

/// Every tile's (edge count, checksum) must equal the single-process
/// full-load oracle decoded over the same shipped plan.
fn assert_oracle_equality(dir: &std::path::Path, report: &paragrapher::distributed::RunReport) {
    let pg = Paragrapher::init();
    let graph = pg
        .open_graph_from_dir(dir, DeviceKind::Ssd, "g", GraphType::CsxWg400, Options::default())
        .expect("oracle open");
    let oracle = oracle_tile_summaries(&graph, report.plan.clone()).expect("oracle decode");
    pg.release_graph(graph);
    assert_eq!(report.tiles.len(), report.plan.num_parts(), "a result for every tile");
    for t in &report.tiles {
        assert_eq!(
            (t.edges, t.checksum),
            oracle[t.tile],
            "tile {} disagrees with the single-process oracle",
            t.tile
        );
    }
}

#[test]
fn two_workers_match_full_load_oracle() {
    with_watchdog(Duration::from_secs(120), || {
        let g = generators::barabasi_albert(3_000, 6, 42);
        let m = g.num_edges();
        let dir = write_graph_dir(&g, "g", "clean");
        let report = run_leader(&LeaderConfig { workers: 2, ..leader_cfg(&dir) })
            .expect("clean 2-worker run");
        assert_eq!(report.workers_spawned, 2);
        assert_eq!(report.workers_lost, 0);
        assert_eq!(report.retiled_tiles, 0);
        assert_eq!(report.edges_delivered, m, "tiles must cover every edge exactly once");
        assert_oracle_equality(&dir, &report);
        std::fs::remove_dir_all(&dir).ok();
    });
}

#[test]
fn kill_worker_mid_tile_retiles_and_covers_every_edge() {
    with_watchdog(Duration::from_secs(120), || {
        let g = generators::barabasi_albert(3_000, 6, 7);
        let m = g.num_edges();
        let dir = write_graph_dir(&g, "g", "kill");
        // Worker 0 ships one tile, then dies mid-second-tile (after the
        // decode, before the result) — the leader sees EOF with a lease
        // outstanding and must retile the orphaned span to the survivor.
        let report = run_leader(&LeaderConfig {
            workers: 2,
            fault_args: vec![(0, "kill-after:1".to_string())],
            ..leader_cfg(&dir)
        })
        .expect("a worker death must not fail the run");
        assert_eq!(report.workers_lost, 1, "exactly the injected death");
        assert!(report.retiled_tiles >= 1, "the orphaned lease must be retiled");
        assert_eq!(report.edges_delivered, m, "full coverage after retiling");
        assert_oracle_equality(&dir, &report);
        std::fs::remove_dir_all(&dir).ok();
    });
}

#[test]
fn stalled_workers_hit_timeout_and_fail_loud() {
    with_watchdog(Duration::from_secs(60), || {
        let g = generators::barabasi_albert(2_000, 5, 3);
        let dir = write_graph_dir(&g, "g", "stall");
        // Every worker stalls on its first tile; the per-tile deadline
        // (not EOF) must fire, and with no survivors the leader must
        // return a loud error — never hang.
        let mut cfg = leader_cfg(&dir);
        cfg.workers = 2;
        cfg.tile_timeout = Duration::from_millis(500);
        cfg.max_attempts = 2;
        cfg.fault_args =
            vec![(0, "stall-after:0".to_string()), (1, "stall-after:0".to_string())];
        let err = run_leader(&cfg).expect_err("an all-stalled run must fail loud");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("unfinished") || msg.contains("attempt"),
            "error must name the unfinished tiles or the attempt bound, got: {msg}"
        );
        std::fs::remove_dir_all(&dir).ok();
    });
}

#[test]
fn truncated_weights_sidecar_fails_cleanly_not_panic() {
    // A weighted graph whose `.weights` sidecar is torn to an odd,
    // too-short byte length: the load must surface a clean error naming
    // the sidecar — the pre-fix code path panicked on the request thread
    // (poisoning buffer locks) instead.
    let edges: Vec<(u32, u32, f32)> =
        (0..900u32).map(|i| (i % 300, (i * 7 + 1) % 300, i as f32 * 0.5)).collect();
    let g = CsrGraph::from_weighted_edges(300, &edges);
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    let mut weights_len = 0usize;
    for (name, data) in webgraph::serialize(&g, "w") {
        if name == "w.weights" {
            weights_len = data.len();
            store.put(&name, data[..data.len() - 7].to_vec()); // torn: short AND misaligned
        } else {
            store.put(&name, data);
        }
    }
    assert!(weights_len >= 8, "fixture must actually have weights");

    let pg = Paragrapher::init();
    let graph = pg
        .open_graph(Arc::clone(&store), "w", GraphType::CsxWg404, Options::default())
        .expect("open succeeds; the tear is in the payload");
    let err = graph.load_whole_graph().expect_err("torn weights must be an error, not a panic");
    let msg = format!("{err:#}");
    assert!(msg.contains("weights sidecar"), "error must name the sidecar, got: {msg}");
    // The failure must not wedge the coordinator: an unweighted-range
    // request path stays usable (buffers were recycled, locks clean).
    let err2 = graph.load_whole_graph().expect_err("still torn on retry");
    assert!(format!("{err2:#}").contains("weights sidecar"));
}

#[test]
fn panicked_set_options_closure_does_not_wedge_later_requests() {
    // A user closure that panics inside `set_options` poisons the options
    // mutex. Pre-fix, every later request died on `.expect("options
    // lock")`; post-fix the coordinator recovers the (structurally valid)
    // config and keeps serving.
    let g = generators::barabasi_albert(1_000, 4, 11);
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    for (name, data) in webgraph::serialize(&g, "p") {
        store.put(&name, data);
    }
    let pg = Paragrapher::init();
    let graph = pg
        .open_graph(Arc::clone(&store), "p", GraphType::CsxWg400, Options::default())
        .expect("open");
    let poisoned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        graph.set_options(|_| panic!("user closure panicked while holding the options lock"));
    }));
    assert!(poisoned.is_err(), "the closure's panic must propagate to its caller");
    let block = graph
        .load_whole_graph()
        .expect("a poisoned options lock must not wedge later requests");
    assert_eq!(block.num_edges(), g.num_edges());
}

#[test]
fn stale_plan_for_another_graph_is_rejected_at_admission() {
    // Same (n, m), different degree distribution: a plan cut from graph
    // A's Elias-Fano sidecar must be rejected by graph B's admission
    // cross-check before any decode is dispatched.
    let star: Vec<(u32, u32)> = (1..=50u32).map(|d| (0, d)).collect();
    let path: Vec<(u32, u32)> = (0..50u32).map(|s| (s, s + 1)).collect();
    let ga = CsrGraph::from_edges(100, &star);
    let gb = CsrGraph::from_edges(100, &path);
    assert_eq!(ga.num_edges(), gb.num_edges());

    let pg = Paragrapher::init();
    let open = |g: &CsrGraph, base: &str| {
        let store = Arc::new(SimStore::new(DeviceKind::Dram));
        for (name, data) in webgraph::serialize(g, base) {
            store.put(&name, data);
        }
        pg.open_graph(store, base, GraphType::CsxWg400, Options::default()).expect("open")
    };
    let graph_a = open(&ga, "a");
    let graph_b = open(&gb, "b");
    let plan = PartitionPlan::two_d(graph_a.offsets_index(), 2, 2);
    graph_a.validate_plan(&plan).expect("a graph admits its own plan");
    let err = graph_b.validate_plan(&plan).expect_err("a foreign plan must be rejected");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("stale or foreign"),
        "rejection must say the plan does not match the local sidecar, got: {msg}"
    );
}
