//! Integration tests for the `GraphSource` abstraction: the random-access
//! successors path vs. range decoding, the decoded-block cache, and the
//! BFS/Afforest out-of-core ports — over both a standalone
//! `WebGraphSource` and an opened coordinator handle (`PgGraph`), which
//! serves *both* request types (streaming blocks and random access).

use std::sync::Arc;

use paragrapher::algorithms::afforest::{afforest, afforest_on};
use paragrapher::algorithms::bfs::{bfs_distances, bfs_distances_on};
use paragrapher::algorithms::count_components;
use paragrapher::coordinator::{GraphType, Options, Paragrapher, PgGraph, VertexRange};
use paragrapher::formats::webgraph;
use paragrapher::formats::{GraphSource, SourceConfig, WebGraphSource};
use paragrapher::graph::{generators, CsrGraph, VertexId};
use paragrapher::storage::{DeviceKind, SimStore};
use paragrapher::util::rng::Xoshiro256;

fn store_with(g: &CsrGraph, base: &str) -> Arc<SimStore> {
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    for (name, data) in webgraph::serialize(g, base) {
        store.put(&name, data);
    }
    store
}

fn open(store: &Arc<SimStore>, base: &str) -> PgGraph {
    Paragrapher::init()
        .open_graph(Arc::clone(store), base, GraphType::CsxWg400, Options::default())
        .expect("open graph")
}

/// 10k-vertex random graph (hubs, isolated vertices, self-loops).
fn random_10k() -> CsrGraph {
    let mut rng = Xoshiro256::seed_from_u64(0x10_000);
    let n = 10_000usize;
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    for _ in 0..60_000 {
        edges.push((
            rng.next_below(n as u64) as VertexId,
            rng.next_below(n as u64) as VertexId,
        ));
    }
    edges.sort_unstable();
    edges.dedup();
    CsrGraph::from_edges(n, &edges)
}

#[test]
fn successors_equals_decode_range_on_10k_vertices() {
    // Acceptance: `GraphSource::successors()` returns identical adjacency
    // to `decode_range` on a 10k-vertex random graph.
    let g = random_10k();
    let store = store_with(&g, "g");
    let src = WebGraphSource::open(&store, "g", SourceConfig::default()).expect("open source");
    assert_eq!(src.num_vertices(), g.num_vertices());
    assert_eq!(src.num_edges(), g.num_edges());
    let n = g.num_vertices();
    // Compare against several range geometries, not just the full decode.
    let full = src.decode_range(0, n).expect("full decode");
    for v in 0..n {
        assert_eq!(src.successors(v).unwrap(), full.neighbors(v), "vertex {v}");
    }
    let mid = src.decode_range(4_321, 5_678).expect("mid decode");
    for (i, v) in (4_321..5_678).enumerate() {
        assert_eq!(src.successors(v).unwrap(), mid.neighbors(i), "vertex {v}");
    }
}

#[test]
fn pg_graph_serves_both_request_types() {
    let g = generators::barabasi_albert(2_000, 6, 11);
    let store = store_with(&g, "g");
    let graph = open(&store, "g");
    // Streaming request type (block pipeline through the event-driven pool).
    let block = GraphSource::decode_range(&graph, 100, 300).expect("decode_range");
    for (i, v) in (100..300).enumerate() {
        assert_eq!(block.neighbors(i), g.neighbors(v as VertexId), "vertex {v}");
    }
    // Random-access request type (decoded-block cache) on the same handle.
    for v in (0..2_000).step_by(37) {
        assert_eq!(
            graph.successors(v).unwrap(),
            g.neighbors(v as VertexId),
            "vertex {v}"
        );
    }
    assert!(graph.stats().random_accesses.load(std::sync::atomic::Ordering::Relaxed) > 0);
    assert!(graph.successors(2_000).is_err(), "out-of-range rejected");
}

#[test]
fn pg_graph_random_access_hits_cache() {
    let g = generators::barabasi_albert(1_000, 5, 13);
    let store = store_with(&g, "g");
    let graph = open(&store, "g");
    let _ = graph.successors(128).unwrap();
    let after_first = graph.decoded_cache_counters();
    assert_eq!(after_first.misses, 1);
    assert_eq!(after_first.hits, 0);
    for _ in 0..9 {
        let _ = graph.successors(128).unwrap();
    }
    let warm = graph.decoded_cache_counters();
    assert_eq!(warm.misses, 1, "hot vertex decoded exactly once");
    assert_eq!(warm.hits, 9);
    assert!(warm.resident_cost > 0);
}

#[test]
fn bfs_unchanged_on_random_access_path() {
    // Acceptance: BFS produces unchanged results when switched to the
    // random-access path — checked over the coordinator handle.
    let g = generators::barabasi_albert(1_500, 5, 19);
    let store = store_with(&g, "g");
    let graph = open(&store, "g");
    for s in [0u32, 7, 1_499] {
        assert_eq!(
            bfs_distances_on(&graph, s).unwrap(),
            bfs_distances(&g, s),
            "source {s}"
        );
    }
}

#[test]
fn afforest_unchanged_on_random_access_path() {
    // Acceptance: Afforest produces unchanged results when switched to the
    // random-access path — same labels, out-of-core pull via the handle.
    let g = generators::road_lattice(30, 30, 0, 1);
    let store = store_with(&g, "g");
    let graph = open(&store, "g");
    let full = afforest(&g, 7);
    let pulled = afforest_on(&graph, 7).unwrap();
    assert_eq!(pulled, full);
    assert_eq!(count_components(&pulled), 1);
}

#[test]
fn streaming_and_random_access_interleave() {
    // Mixed workload over one handle: label-prop-style streaming callbacks
    // while random accesses run — both must see consistent adjacency.
    let g = generators::rmat(9, 6, 23);
    let store = store_with(&g, "g");
    let graph = open(&store, "g");
    let n = g.num_vertices();
    let seen = Arc::new(std::sync::Mutex::new(Vec::<(VertexId, VertexId)>::new()));
    let s2 = Arc::clone(&seen);
    let req = graph
        .csx_get_subgraph(
            VertexRange::new(0, n),
            Arc::new(move |blk| s2.lock().unwrap().extend(blk.iter_edges())),
        )
        .expect("stream request");
    for v in (0..n).step_by(101) {
        assert_eq!(graph.successors(v).unwrap(), g.neighbors(v as VertexId));
    }
    req.wait();
    assert!(!req.is_failed(), "{:?}", req.error());
    let mut got = seen.lock().unwrap().clone();
    got.sort_unstable();
    let mut expected: Vec<(VertexId, VertexId)> = g.iter_edges().collect();
    expected.sort_unstable();
    assert_eq!(got, expected);
}
