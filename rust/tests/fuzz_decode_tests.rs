//! Fuzz-style robustness suite for the WebGraph decoder, mirroring
//! webgraph-rs's `fuzz/` targets with a seeded, deterministic corpus
//! (no external fuzzer in the offline build).
//!
//! Contract under test: feeding the decoder truncated, bit-flipped, or
//! adversarially constructed streams/sidecars must return `Err` (or a
//! well-formed wrong answer for undetectable corruption) — **never** a
//! panic and **never** an unbounded allocation. Every case derives from a
//! fixed seed, so failures reproduce exactly in CI.
//!
//! Corpus size: `TRUNCATED_GRAPH_CASES + BITFLIP_CASES +
//! TRUNCATED_OFFSETS_CASES + OFFSETS_BITFLIP_CASES + adversarial
//! constructions` ≥ 200 (asserted below).

use std::panic::{catch_unwind, AssertUnwindSafe};

use paragrapher::formats::webgraph::{self, WgMeta, WgOffsets, WgParams};
use paragrapher::graph::{generators, CsrGraph};
use paragrapher::storage::sim::ReadCtx;
use paragrapher::storage::{DeviceKind, IoAccount, SimStore};
use paragrapher::util::bitstream::{BitReader, BitWriter};
use paragrapher::util::codes::{int_to_nat, write_gamma, write_zeta, Code, CodeReader};
use paragrapher::util::rng::Xoshiro256;

const TRUNCATED_GRAPH_CASES: usize = 60;
const BITFLIP_CASES: usize = 120;
const TRUNCATED_OFFSETS_CASES: usize = 30;
const OFFSETS_BITFLIP_CASES: usize = 30;
const ADVERSARIAL_CASES: usize = 11;
const DIFFERENTIAL_VALID_CASES: usize = 60;
const DIFFERENTIAL_GARBAGE_CASES: usize = 120;
const DIFFERENTIAL_TRUNCATION_CASES: usize = 40;
const DIFFERENTIAL_GOLOMB_UNARY_CASES: usize = 44;

#[test]
fn corpus_meets_the_size_bar() {
    assert!(
        TRUNCATED_GRAPH_CASES
            + BITFLIP_CASES
            + TRUNCATED_OFFSETS_CASES
            + OFFSETS_BITFLIP_CASES
            + ADVERSARIAL_CASES
            >= 200
    );
    assert!(
        DIFFERENTIAL_VALID_CASES + DIFFERENTIAL_GARBAGE_CASES + DIFFERENTIAL_TRUNCATION_CASES
            >= 200
    );
    assert!(DIFFERENTIAL_GOLOMB_UNARY_CASES >= 40, "unary/Golomb table corpus");
}

/// Seeded corpus graphs: three shapes that exercise intervals, references
/// and residuals differently.
fn corpus_graph(case: usize) -> CsrGraph {
    match case % 3 {
        0 => generators::barabasi_albert(250, 6, case as u64),
        1 => generators::similarity_blocks(240, 24, 8, case as u64),
        _ => generators::road_lattice(16, 16, 10, case as u64),
    }
}

/// Truncating the `.graph` stream by at least one byte must make a
/// full-range decode fail: the final records' bits are gone, and the
/// decoder reads exactly the recorded bits (never padding).
#[test]
fn truncated_graph_stream_always_errors() {
    for case in 0..TRUNCATED_GRAPH_CASES {
        let g = corpus_graph(case);
        let mut rng = Xoshiro256::seed_from_u64(0x7341C + case as u64);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, mut data) in webgraph::serialize(&g, "g") {
            if name.ends_with(".graph") {
                // Keep 0..=85% of the bytes (at least one byte dropped).
                let keep = (data.len() as u64 * rng.next_below(86) / 100) as usize;
                data.truncate(keep);
            }
            store.put(&name, data);
        }
        let acct = IoAccount::new();
        let meta = webgraph::read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        let offs = webgraph::read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        let dec =
            webgraph::Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct)
                .unwrap();
        let result = catch_unwind(AssertUnwindSafe(|| {
            dec.decode_range(0, meta.num_vertices, &acct)
        }));
        let outcome = result.unwrap_or_else(|_| panic!("case {case}: decode panicked"));
        assert!(outcome.is_err(), "case {case}: truncated stream must be an error");
    }
}

/// Bit flips anywhere in the stream: never a panic, and any `Ok` result is
/// structurally well-formed (the corruption decoded to *some* valid shape).
#[test]
fn bitflipped_graph_stream_never_panics() {
    for case in 0..BITFLIP_CASES {
        let g = corpus_graph(case);
        let mut rng = Xoshiro256::seed_from_u64(0xF11B + case as u64);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, mut data) in webgraph::serialize(&g, "g") {
            if name.ends_with(".graph") && !data.is_empty() {
                for _ in 0..1 + rng.next_below(8) {
                    let byte = rng.next_below(data.len() as u64) as usize;
                    data[byte] ^= 1 << rng.next_below(8);
                }
            }
            store.put(&name, data);
        }
        let acct = IoAccount::new();
        let meta = webgraph::read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        let offs = webgraph::read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        let dec =
            webgraph::Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct)
                .unwrap();
        let n = meta.num_vertices;
        let probe = rng.next_below(n as u64) as usize;
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            let range = dec.decode_range(0, n, &acct);
            let one = dec.decode_vertex(probe, &acct);
            (range, one)
        }));
        let (range, one) = outcome.unwrap_or_else(|_| panic!("case {case}: panicked"));
        if let Ok(block) = range {
            assert_eq!(block.num_vertices(), n, "case {case}");
            assert_eq!(block.offsets.len(), n + 1, "case {case}");
            assert_eq!(block.edges.len() as u64, block.num_edges(), "case {case}");
        }
        if let Ok(list) = one {
            assert!(list.len() <= n, "case {case}: degree bounded by n");
        }
    }
}

/// Truncating the offsets sidecar must fail `read_offsets` cleanly.
#[test]
fn truncated_offsets_sidecar_always_errors() {
    for case in 0..TRUNCATED_OFFSETS_CASES {
        let g = corpus_graph(case);
        let mut rng = Xoshiro256::seed_from_u64(0x0FF5 + case as u64);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, mut data) in webgraph::serialize(&g, "g") {
            if name.ends_with(".offsets") {
                // Anywhere from an empty file to one byte short; includes
                // cuts inside the 32-byte v2 header.
                let keep = rng.next_below(data.len() as u64) as usize;
                data.truncate(keep);
            }
            store.put(&name, data);
        }
        let acct = IoAccount::new();
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            webgraph::read_offsets(&store, "g", ReadCtx::default(), &acct)
        }));
        let result = outcome.unwrap_or_else(|_| panic!("case {case}: read_offsets panicked"));
        assert!(result.is_err(), "case {case}: truncated sidecar must be an error");
    }
}

/// Bit-flipped offsets sidecar (including flips that garble the v2 magic
/// into a v1-looking header with a nonsense vertex count): no panics, no
/// OOM-sized allocations — `Err` or a well-formed wrong index.
#[test]
fn bitflipped_offsets_sidecar_never_panics() {
    for case in 0..OFFSETS_BITFLIP_CASES {
        let g = corpus_graph(case);
        let mut rng = Xoshiro256::seed_from_u64(0x0FFB + case as u64);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, mut data) in webgraph::serialize(&g, "g") {
            if name.ends_with(".offsets") && !data.is_empty() {
                for _ in 0..1 + rng.next_below(6) {
                    let byte = rng.next_below(data.len() as u64) as usize;
                    data[byte] ^= 1 << rng.next_below(8);
                }
            }
            store.put(&name, data);
        }
        let acct = IoAccount::new();
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            webgraph::read_offsets(&store, "g", ReadCtx::default(), &acct).map(|_| ())
        }));
        assert!(outcome.is_ok(), "case {case}: read_offsets panicked");
    }
}

/// Properties/sidecar disagreement must fail at open, not panic inside a
/// decode (out-of-bounds offsets lookup).
#[test]
fn inconsistent_properties_rejected_at_open() {
    let g = generators::barabasi_albert(100, 4, 1);
    let store = SimStore::new(DeviceKind::Dram);
    for (name, data) in webgraph::serialize(&g, "g") {
        let data = if name.ends_with(".properties") {
            b"version=1\nnodes=100000\narcs=400\n".to_vec()
        } else {
            data
        };
        store.put(&name, data);
    }
    let acct = IoAccount::new();
    let meta = webgraph::read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
    let offs = webgraph::read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
    assert!(
        webgraph::Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).is_err()
    );
    assert!(
        paragrapher::formats::WebGraphSource::open(
            &store,
            "g",
            paragrapher::formats::SourceConfig::default()
        )
        .is_err()
    );
}

// ---------------------------------------------------------------------------
// Adversarial hand-constructed streams: each targets one decoder validation
// and must fail *quickly* — a 2^40 length read from a γ/ζ code must never
// become a 2^40-element reserve. (ADVERSARIAL_CASES tracks this list.)
// ---------------------------------------------------------------------------

/// Decoder fixture over a hand-built bit stream: open a store containing
/// only the raw stream plus synthetic sidecar vectors, then random-access
/// decode `vertex`.
fn adversarial_decode(
    stream: BitWriter,
    n: usize,
    bit_offsets: Vec<u64>,
    edge_offsets: Vec<u64>,
    vertex: usize,
) -> anyhow::Result<Vec<u32>> {
    let bytes = stream.into_bytes();
    let store = SimStore::new(DeviceKind::Dram);
    store.put("adv.graph", bytes);
    let meta = WgMeta {
        num_vertices: n,
        num_edges: *edge_offsets.last().unwrap(),
        params: WgParams::default(),
        weighted: false,
    };
    let offsets = WgOffsets::from_vecs(&bit_offsets, &edge_offsets)?;
    let acct = IoAccount::new();
    let dec = webgraph::Decoder::open(&store, "adv", &meta, &offsets, ReadCtx::default(), &acct)?;
    dec.decode_vertex(vertex, &acct)
}

/// All records at bit 0; record length = whole stream for every vertex.
fn flat_offsets(n: usize, total_bits: u64) -> Vec<u64> {
    let mut v = vec![0u64];
    v.extend(std::iter::repeat(total_bits).take(n));
    v
}

#[test]
fn adversarial_streams_error_fast_without_allocating() {
    let n = 4usize;

    // 1. Degree far beyond the vertex count.
    let mut w = BitWriter::new();
    write_gamma(&mut w, 1 << 40);
    let bits = w.bit_len();
    let r = catch_unwind(AssertUnwindSafe(|| {
        adversarial_decode(w, n, flat_offsets(n, bits), vec![0; n + 1], 0)
    }))
    .expect("no panic");
    assert!(r.is_err(), "huge degree");

    // 2. Interval length bomb (degree stays plausible so the range check,
    // not the degree guard, is what fires).
    let mut w = BitWriter::new();
    write_gamma(&mut w, 3); // degree
    write_gamma(&mut w, 0); // no reference
    write_gamma(&mut w, 1); // one interval
    write_gamma(&mut w, int_to_nat(0)); // left = v
    write_gamma(&mut w, 1 << 40); // len - min_interval_len
    let bits = w.bit_len();
    let r = catch_unwind(AssertUnwindSafe(|| {
        adversarial_decode(w, n, flat_offsets(n, bits), vec![0, 3, 3, 3, 3], 0)
    }))
    .expect("no panic");
    assert!(r.is_err(), "interval bomb");

    // 3. Interval count above the degree.
    let mut w = BitWriter::new();
    write_gamma(&mut w, 2);
    write_gamma(&mut w, 0);
    write_gamma(&mut w, 1 << 30);
    let bits = w.bit_len();
    let r = catch_unwind(AssertUnwindSafe(|| {
        adversarial_decode(w, n, flat_offsets(n, bits), vec![0, 2, 2, 2, 2], 0)
    }))
    .expect("no panic");
    assert!(r.is_err(), "interval count bomb");

    // 4. Copy-block count bomb (vertex 1 referencing vertex 0).
    let mut w = BitWriter::new();
    write_gamma(&mut w, 2); // degree of vertex 1
    write_gamma(&mut w, 1); // reference v0
    write_gamma(&mut w, 1 << 30); // block count
    let bits = w.bit_len();
    let r = catch_unwind(AssertUnwindSafe(|| {
        adversarial_decode(w, n, vec![0, 0, bits, bits, bits], vec![0, 2, 4, 4, 4], 1)
    }))
    .expect("no panic");
    assert!(r.is_err(), "block count bomb");

    // 5. Copy blocks overrun the reference list.
    let mut w = BitWriter::new();
    write_gamma(&mut w, 2);
    write_gamma(&mut w, 1);
    write_gamma(&mut w, 1); // one block
    write_gamma(&mut w, 10); // copy run of 10 > ref degree 2
    let bits = w.bit_len();
    let r = catch_unwind(AssertUnwindSafe(|| {
        adversarial_decode(w, n, vec![0, 0, bits, bits, bits], vec![0, 2, 4, 4, 4], 1)
    }))
    .expect("no panic");
    assert!(r.is_err(), "copy overrun");

    // 6. Stream ends mid-residuals.
    let mut w = BitWriter::new();
    write_gamma(&mut w, 3);
    write_gamma(&mut w, 0);
    write_gamma(&mut w, 0); // no intervals; 3 residuals expected, none present
    let bits = w.bit_len();
    let r = catch_unwind(AssertUnwindSafe(|| {
        adversarial_decode(w, n, flat_offsets(n, bits), vec![0, 3, 3, 3, 3], 0)
    }))
    .expect("no panic");
    assert!(r.is_err(), "residual exhaustion");

    // 7. ζ shell bomb (h·k + k > 63).
    let mut w = BitWriter::new();
    write_gamma(&mut w, 1);
    write_gamma(&mut w, 0);
    write_gamma(&mut w, 0);
    w.write_unary(40); // ζ3 h = 40 -> 123-bit shell
    w.write_bits(0, 16);
    let bits = w.bit_len();
    let r = catch_unwind(AssertUnwindSafe(|| {
        adversarial_decode(w, n, flat_offsets(n, bits), vec![0, 1, 1, 1, 1], 0)
    }))
    .expect("no panic");
    assert!(r.is_err(), "zeta bomb");

    // 8. Reference pointing before vertex 0.
    let mut w = BitWriter::new();
    write_gamma(&mut w, 1);
    write_gamma(&mut w, 5); // reference 5 at vertex 0
    let bits = w.bit_len();
    let r = catch_unwind(AssertUnwindSafe(|| {
        adversarial_decode(w, n, flat_offsets(n, bits), vec![0, 1, 1, 1, 1], 0)
    }))
    .expect("no panic");
    assert!(r.is_err(), "reference underflow");

    // 9. Residual far outside the vertex range.
    let mut w = BitWriter::new();
    write_gamma(&mut w, 1);
    write_gamma(&mut w, 0);
    write_gamma(&mut w, 0);
    write_zeta(&mut w, int_to_nat(2000), 3); // residual = v + 2000 >= n
    let bits = w.bit_len();
    let r = catch_unwind(AssertUnwindSafe(|| {
        adversarial_decode(w, n, flat_offsets(n, bits), vec![0, 1, 1, 1, 1], 0)
    }))
    .expect("no panic");
    assert!(r.is_err(), "residual out of range");

    // 10. Degree accounting underflow: whole-list copy larger than degree.
    let mut w = BitWriter::new();
    write_gamma(&mut w, 2); // degree 2
    write_gamma(&mut w, 1); // reference v0 (degree 5 per sidecar)
    write_gamma(&mut w, 0); // zero blocks -> copy everything (5 > 2)
    write_gamma(&mut w, 0); // no intervals
    let bits = w.bit_len();
    let r = catch_unwind(AssertUnwindSafe(|| {
        adversarial_decode(w, n, vec![0, 0, bits, bits, bits], vec![0, 5, 7, 7, 7], 1)
    }))
    .expect("no panic");
    assert!(r.is_err(), "degree accounting underflow");

    // 11. Empty stream, non-empty offsets.
    let w = BitWriter::new();
    let r = catch_unwind(AssertUnwindSafe(|| {
        adversarial_decode(w, n, vec![0, 9, 9, 9, 9], vec![0, 1, 1, 1, 1], 0)
    }))
    .expect("no panic");
    assert!(r.is_err(), "empty stream");
}

// ---------------------------------------------------------------------------
// Differential suite: the table-driven fast path (CodeReader) pitted against
// the retained slow-path reference (Code::read) — identical values, identical
// bit positions, identical error-ness, on valid, garbage, and truncated
// streams. This is the contract that lets the decoder select the table path
// per stream without a correctness risk.
// ---------------------------------------------------------------------------

/// Every code family the decoder may select a table for, plus table-less
/// families that must fall through to the reference untouched.
const DIFF_CODES: [Code; 8] = [
    Code::Gamma,
    Code::Delta,
    Code::Zeta(1),
    Code::Zeta(2),
    Code::Zeta(3),
    Code::Zeta(4),
    Code::Zeta(5),
    Code::Unary,
];

/// Decode `bytes` twice — fast and slow — asserting lockstep agreement
/// symbol by symbol until the first error (which must strike both sides).
/// Returns how many symbols decoded successfully.
fn assert_lockstep(code: Code, bytes: &[u8], max_symbols: usize, ctx: &str) -> usize {
    let mut fast = BitReader::new(bytes);
    let mut slow = BitReader::new(bytes);
    let mut reader = CodeReader::new(code);
    for i in 0..max_symbols {
        let f = reader.read(&mut fast);
        let s = code.read(&mut slow);
        match (f, s) {
            (Ok(fv), Ok(sv)) => {
                assert_eq!(fv, sv, "{ctx}: symbol {i} value");
                assert_eq!(
                    fast.bit_pos(),
                    slow.bit_pos(),
                    "{ctx}: symbol {i} bit position"
                );
            }
            (f, s) => {
                assert!(
                    f.is_err() && s.is_err(),
                    "{ctx}: symbol {i} error disagreement (fast {:?}, slow {:?})",
                    f.is_ok(),
                    s.is_ok()
                );
                return i;
            }
        }
    }
    max_symbols
}

/// Valid seeded streams: mixtures of small (table-resident), boundary and
/// huge (slow-path) values for every family; full agreement, zero errors.
#[test]
fn differential_valid_streams() {
    for case in 0..DIFFERENTIAL_VALID_CASES {
        let code = DIFF_CODES[case % DIFF_CODES.len()];
        let mut rng = Xoshiro256::seed_from_u64(0xD1FF + case as u64);
        let values: Vec<u64> = (0..400)
            .map(|i| match i % 5 {
                0 => rng.next_below(16),                 // tiny: always table
                1 => rng.next_below(2048),               // around the table edge
                2 => rng.next_below(1 << 20),            // mid: slow path
                3 => 2040 + rng.next_below(16),          // straddles PEEK_BITS
                _ => rng.next_below(1 << 40),            // huge: slow path
            })
            .map(|v| if code == Code::Unary { v % 700 } else { v })
            .collect();
        let mut w = BitWriter::new();
        for &v in &values {
            code.write(&mut w, v);
        }
        let bytes = w.into_bytes();
        let decoded =
            assert_lockstep(code, &bytes, values.len(), &format!("valid case {case} {code:?}"));
        assert_eq!(decoded, values.len(), "case {case} {code:?}: no spurious error");
        // And the decoded values are the written ones (against the writer,
        // not just against the other decoder).
        let mut r = BitReader::new(&bytes);
        let mut reader = CodeReader::new(code);
        for (i, &v) in values.iter().enumerate() {
            assert_eq!(reader.read(&mut r).unwrap(), v, "case {case} {code:?} symbol {i}");
        }
    }
}

/// Pure garbage: random byte blobs. Whatever happens — values, positions,
/// and the first error — must be identical between the two paths.
#[test]
fn differential_garbage_streams() {
    for case in 0..DIFFERENTIAL_GARBAGE_CASES {
        let code = DIFF_CODES[case % DIFF_CODES.len()];
        let mut rng = Xoshiro256::seed_from_u64(0x6A4B + case as u64);
        let len = 1 + rng.next_below(96) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        assert_lockstep(code, &bytes, 4096, &format!("garbage case {case} {code:?}"));
    }
}

/// Valid streams cut at every kind of boundary (including mid-codeword and
/// inside the final byte): agreement up to and including the first error.
#[test]
fn differential_truncated_streams() {
    for case in 0..DIFFERENTIAL_TRUNCATION_CASES {
        let code = DIFF_CODES[case % DIFF_CODES.len()];
        let mut rng = Xoshiro256::seed_from_u64(0x7A11C + case as u64);
        let values: Vec<u64> = (0..200)
            .map(|_| {
                let v = rng.next_below(1 << 16);
                if code == Code::Unary {
                    v % 300
                } else {
                    v
                }
            })
            .collect();
        let mut w = BitWriter::new();
        for &v in &values {
            code.write(&mut w, v);
        }
        let full = w.into_bytes();
        let keep = (full.len() as u64 * rng.next_below(100) / 100) as usize;
        let cut = &full[..keep];
        let decoded =
            assert_lockstep(code, cut, values.len(), &format!("trunc case {case} {code:?}"));
        // Everything decoded before the cut point must be the real prefix.
        let mut r = BitReader::new(cut);
        let mut reader = CodeReader::new(code);
        for (i, &v) in values.iter().take(decoded).enumerate() {
            assert_eq!(reader.read(&mut r).unwrap(), v, "case {case} {code:?} symbol {i}");
        }
    }
}

/// Golomb parameters for the unary/Golomb table differential corpus:
/// degenerate (m = 1, unary-shaped), non-power-of-two remainders (the
/// truncated minimal-binary split), powers of two, the largest m with any
/// short codeword, and m past the table bound (no-table fallback).
const GOLOMB_MS: [u64; 10] = [1, 2, 3, 5, 7, 8, 60, 64, 1000, 2048];

/// Unary + per-reader Golomb tables vs the slow-path reference: valid
/// streams, pure garbage, and truncations for every `m` class — the
/// satellite corpus pinning the new table families exactly the way the
/// γ/δ/ζ suite pins the static ones.
#[test]
fn differential_golomb_and_unary_tables() {
    for case in 0..DIFFERENTIAL_GOLOMB_UNARY_CASES {
        let code = if case % (GOLOMB_MS.len() + 1) == GOLOMB_MS.len() {
            Code::Unary
        } else {
            Code::Golomb(GOLOMB_MS[case % (GOLOMB_MS.len() + 1)])
        };
        let mut rng = Xoshiro256::seed_from_u64(0x601B + case as u64);
        match case % 3 {
            // Valid streams: small (table-resident) and past-the-window
            // values; full agreement, zero errors.
            0 => {
                let values: Vec<u64> = (0..300)
                    .map(|i| {
                        let bound = match code {
                            Code::Golomb(m) => m * 30, // quotients cross the window
                            _ => 500,
                        };
                        if i % 4 == 0 {
                            rng.next_below(8)
                        } else {
                            rng.next_below(bound.max(1))
                        }
                    })
                    .collect();
                let mut w = BitWriter::new();
                for &v in &values {
                    code.write(&mut w, v);
                }
                let bytes = w.into_bytes();
                let decoded = assert_lockstep(
                    code,
                    &bytes,
                    values.len(),
                    &format!("golomb/unary valid case {case} {code:?}"),
                );
                assert_eq!(decoded, values.len(), "case {case} {code:?}");
                let mut r = BitReader::new(&bytes);
                let mut reader = CodeReader::new(code);
                for (i, &v) in values.iter().enumerate() {
                    assert_eq!(
                        reader.read(&mut r).unwrap(),
                        v,
                        "case {case} {code:?} symbol {i}"
                    );
                }
            }
            // Garbage blobs: values, positions and the first error must be
            // identical between the table and slow paths.
            1 => {
                let len = 1 + rng.next_below(80) as usize;
                let bytes: Vec<u8> =
                    (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
                assert_lockstep(
                    code,
                    &bytes,
                    2048,
                    &format!("golomb/unary garbage case {case} {code:?}"),
                );
            }
            // Truncations at arbitrary byte boundaries.
            _ => {
                let bound = match code {
                    Code::Golomb(m) => m * 20,
                    _ => 200,
                };
                let values: Vec<u64> =
                    (0..150).map(|_| rng.next_below(bound.max(1))).collect();
                let mut w = BitWriter::new();
                for &v in &values {
                    code.write(&mut w, v);
                }
                let full = w.into_bytes();
                let keep = (full.len() as u64 * rng.next_below(100) / 100) as usize;
                let cut = &full[..keep];
                let decoded = assert_lockstep(
                    code,
                    cut,
                    values.len(),
                    &format!("golomb/unary trunc case {case} {code:?}"),
                );
                let mut r = BitReader::new(cut);
                let mut reader = CodeReader::new(code);
                for (i, &v) in values.iter().take(decoded).enumerate() {
                    assert_eq!(
                        reader.read(&mut r).unwrap(),
                        v,
                        "case {case} {code:?} symbol {i}"
                    );
                }
            }
        }
    }
}

/// Hand-built adversarial windows targeting the table edge: codewords whose
/// length is exactly PEEK_BITS, exactly PEEK_BITS+1, and streams ending one
/// bit short of a short codeword.
#[test]
fn differential_table_edge_cases() {
    use paragrapher::util::codes::PEEK_BITS;
    for code in [Code::Gamma, Code::Delta, Code::Zeta(3)] {
        // Find the values whose codeword lengths straddle the peek window.
        let mut at_edge = None;
        let mut past_edge = None;
        for x in 0..(1u64 << 14) {
            let mut w = BitWriter::new();
            code.write(&mut w, x);
            if w.bit_len() == PEEK_BITS as u64 && at_edge.is_none() {
                at_edge = Some(x);
            }
            if w.bit_len() == PEEK_BITS as u64 + 1 && past_edge.is_none() {
                past_edge = Some(x);
            }
            if at_edge.is_some() && past_edge.is_some() {
                break;
            }
        }
        for x in at_edge.into_iter().chain(past_edge) {
            // The codeword alone, then the codeword with its last bit cut.
            let mut w = BitWriter::new();
            code.write(&mut w, x);
            let bit_len = w.bit_len();
            let bytes = w.into_bytes();
            assert_lockstep(code, &bytes, 2, &format!("edge {code:?} x={x}"));
            // Truncate to bit_len - 1 bits by rebuilding the prefix.
            let mut r = BitReader::new(&bytes);
            let mut cutw = BitWriter::new();
            for _ in 0..bit_len - 1 {
                cutw.write_bit(r.read_bit().unwrap());
            }
            let cut = cutw.into_bytes();
            assert_lockstep(code, &cut, 2, &format!("edge-cut {code:?} x={x}"));
        }
    }
}
