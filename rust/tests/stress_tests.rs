//! Deterministic concurrency stress for the coordinator: seeded requester
//! threads drive mixed `successors` / sync `decode_range` / async+cancel
//! traffic over a deliberately tiny buffer pool, bounded by a watchdog.
//!
//! What it proves:
//! * no deadlock and no lost condvar wakeups — the whole run completes
//!   under the watchdog even though every request contends for 2 buffers;
//! * per-request results equal the in-memory `CsrGraph` oracle;
//! * no buffer leaks — after the traffic drains, every buffer is back in
//!   C_IDLE (a block stuck in J_READ_COMPLETED would wedge the pool).
//!
//! All randomness is seeded per thread, so the request *content* is
//! deterministic; only the interleaving varies run to run (which is the
//! point of a stress test).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use paragrapher::coordinator::{GraphType, Options, Paragrapher, PgGraph, VertexRange};
use paragrapher::formats::webgraph;
use paragrapher::graph::{generators, CsrGraph, VertexId};
use paragrapher::storage::{DeviceKind, SimStore};
use paragrapher::util::rng::Xoshiro256;

const WATCHDOG: Duration = Duration::from_secs(120);

/// Run `f` on a helper thread; panic (failing the test) if it does not
/// finish under `timeout` — the deadlock/lost-wakeup detector.
fn with_watchdog<T: Send + 'static>(
    timeout: Duration,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        let out = f();
        let _ = tx.send(());
        out
    });
    match rx.recv_timeout(timeout) {
        Ok(()) => handle.join().expect("stress body panicked"),
        Err(_) => panic!("watchdog: coordinator stress did not finish within {timeout:?}"),
    }
}

fn open_graph(g: &CsrGraph, buffers: usize, buffer_edges: u64) -> (Arc<SimStore>, PgGraph) {
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    for (name, data) in webgraph::serialize(g, "g") {
        store.put(&name, data);
    }
    let graph = Paragrapher::init()
        .open_graph(
            Arc::clone(&store),
            "g",
            GraphType::CsxWg400,
            Options {
                buffers,
                buffer_edges,
                decode_workers: 2,
                source_block_vertices: 16,
                ..Options::default()
            },
        )
        .expect("open");
    (store, graph)
}

#[test]
fn mixed_traffic_over_two_buffers_matches_oracle() {
    with_watchdog(WATCHDOG, || {
        let g = Arc::new(generators::rmat(10, 8, 99)); // 1024 vertices
        let n = g.num_vertices();
        let (_store, graph) = open_graph(&g, 2, 256);
        let graph = Arc::new(graph);
        let buffers = 2;

        const THREADS: u64 = 4;
        const OPS_PER_THREAD: u64 = 30;
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let g = Arc::clone(&g);
            let graph = Arc::clone(&graph);
            handles.push(std::thread::spawn(move || {
                let mut rng = Xoshiro256::seed_from_u64(0x57E55 + t);
                for op in 0..OPS_PER_THREAD {
                    match rng.next_below(4) {
                        // Random access through the decoded-block cache.
                        0 | 1 => {
                            let v = rng.next_below(n as u64) as usize;
                            let got = graph.successors(v).expect("successors");
                            assert_eq!(
                                got,
                                g.neighbors(v as VertexId),
                                "thread {t} op {op}: successors({v})"
                            );
                        }
                        // Blocking range decode through the buffer pipeline.
                        2 => {
                            let lo = rng.next_below(n as u64) as usize;
                            let hi = (lo + 1 + rng.next_below(200) as usize).min(n);
                            let block = graph
                                .csx_get_subgraph_sync(VertexRange::new(lo, hi))
                                .expect("sync subgraph");
                            for (i, v) in (lo..hi).enumerate() {
                                assert_eq!(
                                    block.neighbors(i),
                                    g.neighbors(v as VertexId),
                                    "thread {t} op {op}: range {lo}..{hi} vertex {v}"
                                );
                            }
                        }
                        // Async request, sometimes cancelled mid-flight.
                        _ => {
                            let lo = rng.next_below((n / 2) as u64) as usize;
                            let hi = (lo + 50 + rng.next_below(400) as usize).min(n);
                            let edges = Arc::new(AtomicU64::new(0));
                            let e2 = Arc::clone(&edges);
                            let req = graph
                                .csx_get_subgraph(
                                    VertexRange::new(lo, hi),
                                    Arc::new(move |blk| {
                                        e2.fetch_add(blk.num_edges(), Ordering::SeqCst);
                                    }),
                                )
                                .expect("async subgraph");
                            let cancel = rng.next_below(2) == 0;
                            if cancel {
                                req.cancel();
                            }
                            req.wait(); // must terminate either way
                            assert!(req.is_complete(), "thread {t} op {op}");
                            assert!(!req.is_failed(), "thread {t} op {op}: {:?}", req.error());
                            if !cancel {
                                let expected: u64 =
                                    (lo..hi).map(|v| g.degree(v as VertexId)).sum();
                                assert_eq!(
                                    edges.load(Ordering::SeqCst),
                                    expected,
                                    "thread {t} op {op}: edges for {lo}..{hi}"
                                );
                                assert_eq!(req.edges_delivered(), expected);
                            }
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().expect("requester thread panicked");
        }
        // All traffic drained: every buffer must be back in C_IDLE.
        assert_eq!(graph.idle_buffers(), buffers, "buffer leaked out of C_IDLE");
        // The random-access side kept its cache coherent under concurrency.
        let c = graph.decoded_cache_counters();
        assert!(c.hits + c.misses > 0);
    });
}

#[test]
fn mixed_traffic_under_fault_injection_stays_typed_and_leak_free() {
    // The mixed-traffic stress again, but with a seeded fault plan firing
    // underneath: every request must either deliver oracle-exact data or
    // fail with a *typed* healing error — and the pool must drain back to
    // idle either way. Only eio + stall faults: an undetected bit flip
    // could decode to plausible-but-wrong data, which is exactly the
    // silent failure the typed-error contract rules out of this test.
    use paragrapher::coordinator::PgError;
    use paragrapher::storage::FaultPlan;

    with_watchdog(WATCHDOG, || {
        let g = Arc::new(generators::rmat(10, 8, 77)); // 1024 vertices
        let n = g.num_vertices();
        let (store, graph) = open_graph(&g, 2, 256);
        store.set_fault_plan(Some(Arc::new(
            FaultPlan::parse("eio:*.graph@prob=0.05;stall-ms:*.graph@prob=0.05,ms=1", 0xFA17)
                .expect("fault plan"),
        )));
        let graph = Arc::new(graph);
        let buffers = 2;

        const THREADS: u64 = 4;
        const OPS_PER_THREAD: u64 = 25;
        let faulted = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let g = Arc::clone(&g);
            let graph = Arc::clone(&graph);
            let faulted = Arc::clone(&faulted);
            handles.push(std::thread::spawn(move || {
                let mut rng = Xoshiro256::seed_from_u64(0xFA4C7 + t);
                for op in 0..OPS_PER_THREAD {
                    match rng.next_below(3) {
                        0 => {
                            let v = rng.next_below(n as u64) as usize;
                            match graph.successors(v) {
                                Ok(got) => assert_eq!(
                                    got,
                                    g.neighbors(v as VertexId),
                                    "thread {t} op {op}: successors({v})"
                                ),
                                // The direct path surfaces the healing
                                // error itself: it must be typed.
                                Err(e) => {
                                    assert!(
                                        matches!(
                                            e.downcast_ref::<PgError>(),
                                            Some(PgError::Faulted(_))
                                        ),
                                        "thread {t} op {op}: untyped fault error: {e:#}"
                                    );
                                    faulted.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                        1 => {
                            let lo = rng.next_below(n as u64) as usize;
                            let hi = (lo + 1 + rng.next_below(200) as usize).min(n);
                            match graph.csx_get_subgraph_sync(VertexRange::new(lo, hi)) {
                                Ok(block) => {
                                    for (i, v) in (lo..hi).enumerate() {
                                        assert_eq!(
                                            block.neighbors(i),
                                            g.neighbors(v as VertexId),
                                            "thread {t} op {op}: range {lo}..{hi} vertex {v}"
                                        );
                                    }
                                }
                                Err(_) => {
                                    faulted.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                        _ => {
                            let lo = rng.next_below((n / 2) as u64) as usize;
                            let hi = (lo + 50 + rng.next_below(400) as usize).min(n);
                            let req = graph
                                .csx_get_subgraph(VertexRange::new(lo, hi), Arc::new(|_| {}))
                                .expect("async subgraph submit");
                            req.wait(); // must terminate, healed or failed
                            assert!(req.is_complete(), "thread {t} op {op}");
                            if req.is_failed() {
                                faulted.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().expect("requester thread panicked");
        }
        // Quiesce: failed requests recycle their buffers on completion.
        let mut idle = graph.idle_buffers();
        for _ in 0..400 {
            if idle == buffers {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
            idle = graph.idle_buffers();
        }
        assert_eq!(idle, buffers, "fault paths leaked a buffer out of C_IDLE");

        // The campaign over, the same handle must serve clean traffic.
        store.set_fault_plan(None);
        graph.clear_quarantine();
        for v in [0usize, 3, n / 2, n - 1] {
            assert_eq!(
                graph.successors(v).expect("post-campaign clean read"),
                g.neighbors(v as VertexId)
            );
        }
    });
}

#[test]
fn blocking_requesters_saturate_a_single_buffer_pool() {
    // 8 threads × sequential whole-range loads through ONE buffer: the
    // request manager parks on the pool condvar for almost every block. A
    // lost wakeup anywhere stalls this test into the watchdog.
    with_watchdog(WATCHDOG, || {
        let g = Arc::new(generators::barabasi_albert(600, 6, 5));
        let n = g.num_vertices();
        let (_store, graph) = open_graph(&g, 1, 128);
        let graph = Arc::new(graph);
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let g = Arc::clone(&g);
            let graph = Arc::clone(&graph);
            handles.push(std::thread::spawn(move || {
                for round in 0..5 {
                    let block = graph
                        .csx_get_subgraph_sync(VertexRange::new(0, n))
                        .expect("whole load");
                    assert_eq!(
                        block.num_edges(),
                        g.num_edges(),
                        "thread {t} round {round}"
                    );
                }
            }));
        }
        for h in handles {
            h.join().expect("requester thread panicked");
        }
        assert_eq!(graph.idle_buffers(), 1, "the single buffer must be idle again");
    });
}

#[test]
fn partition_stream_consumers_with_cancellation() {
    // Two consumers drain partitioned requests over the tiny 2-buffer
    // pool while a third thread cancels streams mid-flight; repeated with
    // seeded variation. Proves: no deadlock between the staging window,
    // the pool condvar and consumer pulls; cancelled streams release
    // their buffers; surviving streams deliver every edge exactly once.
    with_watchdog(WATCHDOG, || {
        let g = Arc::new(generators::rmat(10, 8, 33)); // 1024 vertices
        let m = g.num_edges();
        let (_store, graph) = open_graph(&g, 2, 256);
        let graph = Arc::new(graph);
        for round in 0..6u64 {
            let cancel_this_round = round % 2 == 1;
            let stream =
                Arc::new(graph.csx_get_partitions(24).expect("partitioned request"));
            let edges = Arc::new(AtomicU64::new(0));
            let mut consumers = Vec::new();
            for t in 0..2u64 {
                let stream = Arc::clone(&stream);
                let edges = Arc::clone(&edges);
                consumers.push(std::thread::spawn(move || loop {
                    match stream.next() {
                        Ok(Some(p)) => {
                            // Touch the data like a real consumer.
                            let mut sum = 0u64;
                            for (s, d) in p.iter_edges() {
                                sum += (s ^ d) as u64;
                            }
                            std::hint::black_box(sum);
                            edges.fetch_add(p.num_edges(), Ordering::SeqCst);
                        }
                        Ok(None) => break,
                        Err(e) => panic!("consumer {t}: {e}"),
                    }
                }));
            }
            let canceller = if cancel_this_round {
                let stream = Arc::clone(&stream);
                let mut rng = Xoshiro256::seed_from_u64(0xCA11 + round);
                let delay = Duration::from_micros(rng.next_below(2000));
                Some(std::thread::spawn(move || {
                    std::thread::sleep(delay);
                    stream.cancel();
                }))
            } else {
                None
            };
            for c in consumers {
                c.join().expect("consumer panicked");
            }
            if let Some(c) = canceller {
                c.join().expect("canceller panicked");
            }
            if !cancel_this_round {
                assert_eq!(
                    edges.load(Ordering::SeqCst),
                    m,
                    "round {round}: full drain must deliver every edge once"
                );
                let counters = stream.counters();
                assert_eq!(counters.consumed, 24, "round {round}");
            }
            drop(stream); // joins the dispatcher (sole Arc owner here)
            // In-flight decodes recycle on completion; wait for quiescence.
            let mut idle = graph.idle_buffers();
            for _ in 0..400 {
                if idle == 2 {
                    break;
                }
                std::thread::sleep(Duration::from_millis(5));
                idle = graph.idle_buffers();
            }
            assert_eq!(idle, 2, "round {round}: partition path leaked a buffer");
        }
    });
}

#[test]
fn cancel_storm_terminates_and_leaks_nothing() {
    with_watchdog(WATCHDOG, || {
        let g = Arc::new(generators::barabasi_albert(2000, 8, 17));
        let n = g.num_vertices();
        let (_store, graph) = open_graph(&g, 2, 200);
        let graph = Arc::new(graph);
        let mut requests = Vec::new();
        for i in 0..32 {
            let req = graph
                .csx_get_subgraph(VertexRange::new(0, n), Arc::new(|_| {}))
                .expect("request");
            if i % 2 == 0 {
                req.cancel();
            }
            requests.push(req);
        }
        for req in &requests {
            req.wait();
            assert!(req.is_complete());
        }
        assert_eq!(graph.idle_buffers(), 2, "cancel paths must recycle buffers");
    });
}
