//! Fault injection and the self-healing read path, end to end:
//! seeded determinism of the fault plan itself, transparent retry of
//! transient faults, checksum-classified corruption (`PgError::Corrupt`,
//! never retried), quarantine after an exhausted retry budget, and the
//! per-file mmap→pread degradation — all observed through the public
//! coordinator API plus the four `fault.*`/`read.*`/`block.*` registry
//! counters.

use std::sync::Arc;
use std::time::Duration;

use paragrapher::coordinator::{GraphType, Options, Paragrapher, PgError, PgGraph};
use paragrapher::formats::webgraph;
use paragrapher::graph::{generators, CsrGraph, VertexId};
use paragrapher::obs::names;
use paragrapher::storage::{DeviceKind, FaultPlan, IoAccount, ReadCtx, ReadMethod, SimStore};
use paragrapher::util::rng::Xoshiro256;

fn open_graph(g: &CsrGraph, opts: Options) -> (Arc<SimStore>, PgGraph) {
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    for (name, data) in webgraph::serialize(g, "g") {
        store.put(&name, data);
    }
    let graph = Paragrapher::init()
        .open_graph(Arc::clone(&store), "g", GraphType::CsxWg400, opts)
        .expect("open");
    (store, graph)
}

/// Healing options with no real sleeping, so exhausting the retry budget
/// is cheap inside a test.
fn fast_heal(retries: u32) -> Options {
    Options {
        read_retries: retries,
        retry_backoff: Duration::ZERO,
        source_block_vertices: 16,
        ..Options::default()
    }
}

fn counter(graph: &PgGraph, key: &str) -> u64 {
    graph.metrics_snapshot().counters.get(key).copied().unwrap_or(0)
}

#[test]
fn fault_plan_decisions_are_seed_deterministic() {
    let spec = "eio:*.graph@prob=0.3;short-read:*.graph@prob=0.2;stall-ms:*.ef@prob=0.5,ms=1";
    let a = FaultPlan::parse(spec, 42).expect("plan a");
    let b = FaultPlan::parse(spec, 42).expect("plan b");
    let c = FaultPlan::parse(spec, 43).expect("plan c");
    // Identical (file, offset, len) sequences against identically-seeded
    // plans must produce identical decisions; a different seed must not.
    let mut rng = Xoshiro256::seed_from_u64(7);
    let mut diverged = false;
    for _ in 0..512 {
        let file = if rng.next_below(2) == 0 { "g.graph" } else { "g.ef" };
        let offset = rng.next_below(1 << 20);
        let len = 1 + rng.next_below(4096);
        let da = a.decide(file, offset, len);
        let db = b.decide(file, offset, len);
        assert_eq!(da, db, "same seed, same read, different decision");
        diverged |= da != c.decide(file, offset, len);
    }
    assert_eq!(a.injected(), b.injected(), "injected counts must track together");
    assert!(diverged, "a different seed never changed a single decision");
}

#[test]
fn transient_fault_heals_by_retry_and_matches_oracle() {
    let g = generators::barabasi_albert(2000, 8, 11);
    let (store, graph) = open_graph(&g, fast_heal(3));
    // Installed after open, so the one-shot fault lands on the request
    // path, not on open-time metadata reads.
    store.set_fault_plan(Some(Arc::new(
        FaultPlan::parse("eio:*.graph@nth=1,count=1", 1).expect("plan"),
    )));
    let got = graph.successors(17).expect("healed read must succeed");
    assert_eq!(got, g.neighbors(17 as VertexId));
    assert!(counter(&graph, names::READ_RETRIES) >= 1, "heal must go through a retry");
    assert!(counter(&graph, names::FAULT_INJECTED) >= 1);
    assert_eq!(graph.quarantined_blocks(), 0, "a healed block must not quarantine");
}

#[test]
fn checksum_mismatch_is_corrupt_and_never_retried() {
    let g = generators::barabasi_albert(2000, 8, 13);
    let (store, graph) = open_graph(&g, fast_heal(5));
    // Corrupt the at-rest evidence (chunk 0's digest in the sidecar), not
    // the stream: classification must then call any failure in that chunk
    // corruption, deterministically. Done after open so the open-time
    // header gate still passes.
    let sums_file = store.open("g.checksums").expect("sidecar");
    let mut sums = sums_file.read(0, sums_file.len(), ReadCtx::default(), &IoAccount::new());
    sums[16] ^= 0x01;
    drop(sums_file);
    store.put("g.checksums", sums);
    // A persistent fault forces the read to fail so classification runs.
    store.set_fault_plan(Some(Arc::new(
        FaultPlan::parse("eio:*.graph@count=inf", 2).expect("plan"),
    )));

    let err = graph.successors(5).expect_err("corrupt chunk must fail");
    assert!(
        matches!(err.downcast_ref::<PgError>(), Some(PgError::Corrupt(_))),
        "want PgError::Corrupt, got: {err:#}"
    );
    assert_eq!(
        counter(&graph, names::READ_RETRIES),
        0,
        "corruption at rest must never be retried"
    );
    assert_eq!(graph.quarantined_blocks(), 1);
    assert!(counter(&graph, names::BLOCK_QUARANTINED) >= 1);
    // The quarantined block now fails fast, still without retries.
    assert!(graph.successors(5).is_err());
    assert_eq!(counter(&graph, names::READ_RETRIES), 0);
}

#[test]
fn exhausted_retries_quarantine_then_clear_heals() {
    let g = generators::barabasi_albert(2000, 8, 17);
    let (store, graph) = open_graph(&g, fast_heal(2));
    store.set_fault_plan(Some(Arc::new(
        FaultPlan::parse("eio:*.graph@count=inf", 3).expect("plan"),
    )));

    let v = 40;
    let err = graph.successors(v).expect_err("persistent fault must fail");
    assert!(
        matches!(err.downcast_ref::<PgError>(), Some(PgError::Faulted(_))),
        "want PgError::Faulted, got: {err:#}"
    );
    assert_eq!(graph.quarantined_blocks(), 1);
    let retries = counter(&graph, names::READ_RETRIES);
    assert!(retries >= 2, "the whole retry budget must be spent, saw {retries}");

    // Fail-fast: the second request must not burn the budget again.
    let err = graph.successors(v).expect_err("quarantined block must fail fast");
    assert!(matches!(err.downcast_ref::<PgError>(), Some(PgError::Faulted(_))));
    assert_eq!(counter(&graph, names::READ_RETRIES), retries, "fast path must not retry");
    assert_eq!(counter(&graph, names::BLOCK_QUARANTINED), 1);

    // Operator intervention: lift the fault and the quarantine, and the
    // same handle serves the same block correctly again.
    store.set_fault_plan(None);
    assert_eq!(graph.clear_quarantine(), 1);
    let got = graph.successors(v).expect("cleared block must heal");
    assert_eq!(got, g.neighbors(v as VertexId));
}

#[test]
fn repeated_mmap_faults_degrade_to_pread_and_surface_in_counters() {
    // Degradation needs a rooted (real-file) store so Mmap is a real
    // mapping; the graph is served from a temp dir fixture.
    let g = generators::barabasi_albert(2000, 8, 19);
    let dir = std::env::temp_dir().join(format!("pg_fault_mmap_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("mkdir");
    for (name, data) in webgraph::serialize(&g, "g") {
        std::fs::write(dir.join(&name), &data).expect("write fixture");
    }
    let pg = Paragrapher::init();
    let graph = pg
        .open_graph_from_dir(
            &dir,
            DeviceKind::Ssd,
            "g",
            GraphType::CsxWg400,
            Options {
                read_ctx: ReadCtx { method: ReadMethod::Mmap, ..ReadCtx::default() },
                ..fast_heal(3)
            },
        )
        .expect("open from dir");
    let store = Arc::clone(graph.store());
    // Two mapped faults cross the degradation threshold; the third
    // attempt goes through pread (and the plan is exhausted), so the
    // request *heals* — degraded, retried, never quarantined.
    store.set_fault_plan(Some(Arc::new(
        FaultPlan::parse("eio:*.graph@count=2", 4).expect("plan"),
    )));
    let got = graph.successors(23).expect("degraded read must heal");
    assert_eq!(got, g.neighbors(23 as VertexId));
    assert!(store.degraded_files() >= 1, "the .graph file must be degraded to pread");
    assert!(counter(&graph, names::READ_DEGRADED) >= 1);
    assert!(counter(&graph, names::READ_RETRIES) >= 2);
    assert_eq!(graph.quarantined_blocks(), 0);
    // Lifting the plan also lifts the degradation.
    store.set_fault_plan(None);
    assert_eq!(store.degraded_files(), 0);
    pg.release_graph(graph);
    std::fs::remove_dir_all(&dir).ok();
}
