//! Integration tests of the partitioned request subsystem: plan coverage
//! properties over the real coordinator, partitioned-algorithm equality
//! with full-load oracles, prefetch/backpressure behaviour, and the §3
//! interleaved-vs-sequential envelope.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use paragrapher::algorithms::partitioned::{
    afforest_partitioned, bfs_partitioned, for_each_partition, wcc_jtcc_partitioned,
    wcc_label_prop_partitioned,
};
use paragrapher::coordinator::{GraphType, Options, Paragrapher, PgGraph};
use paragrapher::formats::webgraph;
use paragrapher::graph::{generators, CsrGraph, VertexId};
use paragrapher::partition::PartitionPlan;
use paragrapher::storage::{DeviceKind, SimStore};

fn open_graph(g: &CsrGraph, buffers: usize) -> (Arc<SimStore>, PgGraph) {
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    for (name, data) in webgraph::serialize(g, "g") {
        store.put(&name, data);
    }
    let graph = Paragrapher::init()
        .open_graph(
            Arc::clone(&store),
            "g",
            GraphType::CsxWg400,
            Options { buffers, buffer_edges: 4096, ..Options::default() },
        )
        .expect("open");
    (store, graph)
}

/// Drain a stream and return every delivered `(src, dst)` edge.
fn drain_edges(graph: &PgGraph, plan: PartitionPlan, consumers: usize) -> Vec<(u32, u32)> {
    let stream = graph.get_partitions(plan).expect("get_partitions");
    let edges = Mutex::new(Vec::new());
    for_each_partition(&stream, consumers, |p| {
        let mut batch: Vec<(u32, u32)> = p.iter_edges().collect();
        edges.lock().unwrap().append(&mut batch);
        Ok(())
    })
    .expect("drain");
    edges.into_inner().unwrap()
}

fn edge_multiset(g: &CsrGraph) -> HashMap<(u32, u32), usize> {
    let mut m = HashMap::new();
    for (s, d) in g.iter_edges() {
        *m.entry((s, d)).or_insert(0) += 1;
    }
    m
}

fn assert_exact_cover(g: &CsrGraph, delivered: &[(u32, u32)]) {
    let mut got: HashMap<(u32, u32), usize> = HashMap::new();
    for &e in delivered {
        *got.entry(e).or_insert(0) += 1;
    }
    assert_eq!(delivered.len() as u64, g.num_edges(), "edge count");
    assert_eq!(got, edge_multiset(g), "edge multiset");
}

/// Property: every plan kind covers all m edges exactly once, through the
/// real coordinator, on skewed and empty-vertex graphs.
#[test]
fn plans_cover_every_edge_exactly_once() {
    let skewed = generators::rmat(9, 6, 5);
    let mut sparse_edges = vec![(0u32, 1u32), (0, 40), (77, 3)];
    sparse_edges.sort_unstable();
    let sparse = CsrGraph::from_edges(120, &sparse_edges); // mostly empty vertices
    for (gi, g) in [skewed, sparse, generators::barabasi_albert(700, 5, 9)]
        .into_iter()
        .enumerate()
    {
        let (_store, graph) = open_graph(&g, 3);
        let offs = graph.offsets_index();
        for (pi, plan) in [
            PartitionPlan::one_d(offs, 5),
            PartitionPlan::one_d(offs, 64),
            PartitionPlan::two_d(offs, 3, 4),
            PartitionPlan::two_d(offs, 1, 7),
            PartitionPlan::coo(offs, 6),
            PartitionPlan::coo(offs, 37),
        ]
        .into_iter()
        .enumerate()
        {
            plan.check().expect("plan consistency");
            let delivered = drain_edges(&graph, plan, 2);
            assert_eq!(
                delivered.len() as u64,
                g.num_edges(),
                "graph {gi} plan {pi}: delivered count"
            );
            assert_exact_cover(&g, &delivered);
        }
    }
}

/// Property: every plan kind survives the cross-process shipping leg —
/// to_json → text → Json::parse → from_json reconstructs the identical
/// plan, and the reconstructed ("received") plan is served by the real
/// coordinator with exact edge coverage, as if a leader had shipped it to
/// a machine.
#[test]
fn shipped_plan_round_trips_and_serves() {
    use paragrapher::util::json::Json;
    let g = generators::rmat(8, 5, 21);
    let (_store, graph) = open_graph(&g, 3);
    let offs = graph.offsets_index();
    for plan in [
        PartitionPlan::one_d(offs, 6),
        PartitionPlan::two_d(offs, 2, 3),
        PartitionPlan::coo(offs, 9),
    ] {
        let wire = plan.to_json().to_string_pretty();
        let received =
            PartitionPlan::from_json(&Json::parse(&wire).expect("parse")).expect("from_json");
        assert_eq!(received, plan, "kind {:?}", plan.kind);
        let delivered = drain_edges(&graph, received, 2);
        assert_exact_cover(&g, &delivered);
    }
    // A tampered document must be refused before it reaches the server.
    let wire = PartitionPlan::one_d(offs, 4).to_json().to_string_pretty();
    let mut doc = Json::parse(&wire).unwrap();
    doc.set("num_edges", (g.num_edges() + 1) as f64);
    assert!(PartitionPlan::from_json(&doc).is_err(), "edge-count mismatch accepted");
}

/// Partitioned WCC / BFS / Afforest equal their full-load counterparts.
#[test]
fn partitioned_algorithms_match_full_load() {
    let g = generators::rmat(9, 4, 11).symmetrize();
    let (_store, graph) = open_graph(&g, 3);
    let n = g.num_vertices();

    // JT-CC over COO partitions == full-load JT-CC (order-invariant).
    let full_uf = paragrapher::algorithms::jtcc::JtUnionFind::new(n, 5);
    for (s, d) in g.iter_edges() {
        full_uf.union(s, d);
    }
    let full = paragrapher::algorithms::canonicalize(&full_uf.labels());
    let part = wcc_jtcc_partitioned(|| graph.coo_get_partitions(7), n, 3, 5).expect("jtcc");
    assert_eq!(part, full);

    // Label prop over 1D partitions == full-load label prop.
    let full_lp = paragrapher::algorithms::label_prop::wcc_label_prop(
        &g,
        paragrapher::algorithms::label_prop::StepEngine::Native,
    )
    .expect("full label prop");
    let part_lp =
        wcc_label_prop_partitioned(|| graph.csx_get_partitions(6), n, 2).expect("part lp");
    assert_eq!(part_lp, full_lp);

    // BFS over 2D tiles == full-load BFS distances.
    for src in [0u32, 99] {
        let full_bfs = paragrapher::algorithms::bfs::bfs_distances(&g, src);
        let part_bfs =
            bfs_partitioned(|| graph.csx_get_partitions_2d(3, 3), n, 2, src).expect("bfs");
        assert_eq!(part_bfs, full_bfs, "source {src}");
    }

    // Afforest over 1D partitions == full-load Afforest (same seed).
    let full_aff = paragrapher::algorithms::afforest::afforest(&g, 7);
    let part_aff =
        afforest_partitioned(|| graph.csx_get_partitions(5), n, 2, 7).expect("afforest");
    assert_eq!(
        paragrapher::algorithms::count_components(&part_aff),
        paragrapher::algorithms::count_components(&full_aff)
    );
    // Same component structure, not just the same count.
    let truth = paragrapher::algorithms::canonicalize(
        &paragrapher::algorithms::bfs::wcc_by_bfs(&g),
    );
    assert_eq!(part_aff, truth);
}

/// 2D tiles carry only their target columns; the per-row union of a row
/// group's tiles reassembles the full adjacency.
#[test]
fn two_d_tiles_filter_targets() {
    let g = generators::barabasi_albert(400, 6, 3);
    let (_store, graph) = open_graph(&g, 2);
    let plan = PartitionPlan::two_d(graph.offsets_index(), 2, 3);
    let stream = graph.get_partitions(plan).expect("stream");
    let collected: Mutex<Vec<(usize, usize, Vec<(u32, u32)>)>> = Mutex::new(Vec::new());
    for_each_partition(&stream, 2, |p| {
        for (_, d) in p.iter_edges() {
            assert!(
                p.part.targets.contains(d as usize),
                "edge target {d} outside tile columns {:?}",
                p.part.targets
            );
        }
        collected.lock().unwrap().push((
            p.part.vertices.start,
            p.part.targets.start,
            p.iter_edges().collect(),
        ));
        Ok(())
    })
    .expect("drain");
    let mut all: Vec<(u32, u32)> =
        collected.into_inner().unwrap().into_iter().flat_map(|(_, _, e)| e).collect();
    let mut expect: Vec<(u32, u32)> = g.iter_edges().collect();
    all.sort_unstable();
    expect.sort_unstable();
    assert_eq!(all, expect);
}

/// COO partitions deliver exact edge spans even when a cut lands inside a
/// vertex's row.
#[test]
fn coo_partitions_trim_exactly() {
    // One hub vertex with a long row guarantees in-row cuts.
    let mut edges: Vec<(u32, u32)> = (1..60).map(|d| (0u32, d as u32)).collect();
    edges.extend([(5, 0), (6, 2), (59, 1)]);
    edges.sort_unstable();
    let g = CsrGraph::from_edges(60, &edges);
    let (_store, graph) = open_graph(&g, 2);
    let plan = PartitionPlan::coo(graph.offsets_index(), 7);
    let stream = graph.get_partitions(plan).expect("stream");
    let counts = Mutex::new(Vec::new());
    for_each_partition(&stream, 1, |p| {
        counts.lock().unwrap().push((p.part.index, p.num_edges()));
        Ok(())
    })
    .expect("drain");
    let mut got = counts.into_inner().unwrap();
    got.sort_unstable();
    let m = g.num_edges();
    for (k, (_, edges)) in got.iter().enumerate() {
        let expect = m * (k as u64 + 1) / 7 - m * k as u64 / 7;
        assert_eq!(*edges, expect, "partition {k} edge share");
    }
}

/// The stream honors cancellation mid-flight and the pool leaks no
/// buffers afterwards.
#[test]
fn cancellation_releases_buffers() {
    let g = generators::barabasi_albert(3000, 8, 5);
    let (_store, graph) = open_graph(&g, 2);
    let stream = graph.csx_get_partitions(40).expect("stream");
    // Consume a couple, then cancel.
    let mut taken = 0;
    while taken < 2 {
        match stream.next().expect("next") {
            Some(_) => taken += 1,
            None => break,
        }
    }
    stream.cancel();
    assert!(stream.next().expect("after cancel").is_none());
    drop(stream); // joins the dispatcher
    // All buffers must be back in C_IDLE (leak check, as in the stress
    // suite). In-flight decodes recycle on completion; give them a beat.
    for _ in 0..200 {
        if graph.idle_buffers() == 2 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert_eq!(graph.idle_buffers(), 2, "cancelled stream leaked a buffer");
    // The graph still serves requests afterwards.
    let labels = wcc_jtcc_partitioned(|| graph.coo_get_partitions(4), g.num_vertices(), 2, 3)
        .expect("post-cancel stream");
    assert_eq!(labels.len(), g.num_vertices());
}

/// Interleaved end-to-end time sits strictly below load-then-execute and
/// inside the §3 model envelope on a slow tier (acceptance criterion).
#[test]
fn interleaved_beats_sequential_within_envelope() {
    let g = generators::barabasi_albert(4000, 8, 21);
    let store = SimStore::new(DeviceKind::Hdd);
    paragrapher::formats::FormatKind::WebGraph.write_to_store(&g, &store, "g");
    let acct = paragrapher::storage::IoAccount::new();
    let offs = webgraph::read_offsets(
        &store,
        "g",
        paragrapher::storage::sim::ReadCtx::default(),
        &acct,
    )
    .expect("offsets");
    let plan = PartitionPlan::one_d(&offs, 12);
    for window in [1usize, 3, 8] {
        let run = paragrapher::bench::workloads::modeled_interleaved_run(
            &store, "g", &plan, window, 40.0,
        )
        .expect("run");
        assert!(
            run.interleaved < run.sequential,
            "window {window}: interleaved {} !< sequential {}",
            run.interleaved,
            run.sequential
        );
        assert!(
            run.interleaved >= run.envelope_floor() - 1e-12,
            "window {window}: below the §3 floor"
        );
        assert!(run.overlap > 0.0 && run.overlap <= 1.0);
    }
}

/// The model-driven prefetch window adapts to the storage tier of the
/// opened store: faster tiers stage deeper.
#[test]
fn prefetch_window_adapts_to_tier() {
    let g = generators::barabasi_albert(2000, 6, 3);
    let mut depths = Vec::new();
    for device in [DeviceKind::Hdd, DeviceKind::Ssd, DeviceKind::Dram] {
        let store = Arc::new(SimStore::new(device));
        for (name, data) in webgraph::serialize(&g, "g") {
            store.put(&name, data);
        }
        let graph = Paragrapher::init()
            .open_graph(
                Arc::clone(&store),
                "g",
                GraphType::CsxWg400,
                Options { buffers: 16, ..Options::default() },
            )
            .expect("open");
        depths.push(graph.auto_prefetch_window());
    }
    assert!(depths[0] <= depths[1] && depths[1] <= depths[2], "depths {depths:?}");
    assert!(depths[0] >= 1 && depths[2] <= 32);
    // Pinning the window through Options overrides the model.
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    for (name, data) in webgraph::serialize(&g, "g") {
        store.put(&name, data);
    }
    let graph = Paragrapher::init()
        .open_graph(
            Arc::clone(&store),
            "g",
            GraphType::CsxWg400,
            Options { buffers: 2, prefetch_window: 1, ..Options::default() },
        )
        .expect("open");
    let stream = graph.csx_get_partitions(6).expect("stream");
    let edges = AtomicU64::new(0);
    for_each_partition(&stream, 1, |p| {
        edges.fetch_add(p.num_edges(), Ordering::Relaxed);
        Ok(())
    })
    .expect("drain");
    assert_eq!(edges.load(Ordering::Relaxed), g.num_edges());
}

/// Plan metadata survives serialization and a foreign plan is rejected.
#[test]
fn plan_validation_and_metadata() {
    let g = generators::barabasi_albert(500, 4, 9);
    let (_store, graph) = open_graph(&g, 2);
    let plan = PartitionPlan::one_d(graph.offsets_index(), 4);
    let json = plan.to_json().to_string_pretty();
    assert!(json.contains("\"balance_factor\""), "{json}");

    // A plan for a different graph must be rejected up front.
    let other = generators::barabasi_albert(200, 3, 1);
    let (_s2, graph2) = open_graph(&other, 2);
    let foreign = PartitionPlan::one_d(graph2.offsets_index(), 4);
    assert!(graph.get_partitions(foreign).is_err(), "foreign plan accepted");
}

/// Partitioned streaming on a weighted-capable handle and per-vertex rows:
/// 1D partitions deliver complete adjacency rows in vertex order within
/// each partition.
#[test]
fn one_d_rows_are_complete() {
    let g = generators::similarity_blocks(300, 32, 8, 5);
    let (_store, graph) = open_graph(&g, 2);
    let stream = graph.csx_get_partitions(5).expect("stream");
    for_each_partition(&stream, 2, |p| {
        for i in 0..p.block.num_vertices() {
            let v = p.block.first_vertex + i;
            assert_eq!(
                p.block.neighbors(i),
                g.neighbors(v as VertexId),
                "vertex {v} row"
            );
        }
        Ok(())
    })
    .expect("drain");
}
