//! Whole-pipeline integration: generate → serialize in every format →
//! load through every path → run WCC — the loaded graph and the analytics
//! results must agree across formats, devices and engines.

use std::sync::Arc;

use paragrapher::algorithms::{afforest::afforest, bfs::wcc_by_bfs, count_components, jtcc::JtUnionFind};
use paragrapher::coordinator::{GraphType, Options, Paragrapher, VertexRange};
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::{self, Dataset};
use paragrapher::storage::sim::ReadCtx;
use paragrapher::storage::{DeviceKind, IoAccount, SimStore};

#[test]
fn every_format_loads_identically_on_every_device() {
    let g = generators::rmat(8, 8, 7);
    for device in [DeviceKind::Hdd, DeviceKind::Ssd, DeviceKind::Nas] {
        let store = SimStore::new(device);
        for fk in FormatKind::ALL {
            let base = format!("g-{:?}", fk);
            fk.write_to_store(&g, &store, &base);
            store.drop_cache();
            let accounts: Vec<IoAccount> = (0..3).map(|_| IoAccount::new()).collect();
            let loaded = fk
                .load_full(&store, &base, ReadCtx::default(), &accounts)
                .unwrap_or_else(|e| panic!("{:?} on {}: {e}", fk, device.name()));
            assert_eq!(loaded, g, "{:?} on {}", fk, device.name());
            // Cold loads must actually touch the device.
            let bytes: u64 = accounts.iter().map(|a| a.bytes_read()).sum();
            assert!(bytes > 0, "{:?} on {} read nothing", fk, device.name());
        }
    }
}

#[test]
fn wcc_agrees_across_all_paths() {
    let g = Dataset::Rd.generate(1, 5);
    let truth = count_components(&wcc_by_bfs(&g));

    // Path 1: GAPBS-style — binary CSX full load + Afforest.
    let store = SimStore::new(DeviceKind::Ssd);
    FormatKind::BinCsx.write_to_store(&g, &store, "b");
    let accounts: Vec<IoAccount> = (0..2).map(|_| IoAccount::new()).collect();
    let loaded = FormatKind::BinCsx
        .load_full(&store, "b", ReadCtx::default(), &accounts)
        .expect("bin csx load");
    let aff = count_components(&afforest(&loaded, 3));
    assert_eq!(aff, truth, "afforest vs bfs");

    // Path 2: ParaGrapher — streaming JT-CC over async WebGraph blocks.
    let store2 = Arc::new(SimStore::new(DeviceKind::Hdd));
    FormatKind::WebGraph.write_to_store(&g, &store2, "w");
    store2.drop_cache();
    let graph = Paragrapher::init()
        .open_graph(
            Arc::clone(&store2),
            "w",
            GraphType::CsxWg400,
            Options { buffers: 3, buffer_edges: 4096, ..Options::default() },
        )
        .expect("open");
    let uf = Arc::new(JtUnionFind::new(graph.num_vertices(), 11));
    let uf2 = Arc::clone(&uf);
    let req = graph
        .csx_get_subgraph(
            VertexRange::new(0, graph.num_vertices()),
            Arc::new(move |blk| {
                for (s, d) in blk.iter_edges() {
                    uf2.union(s, d);
                }
            }),
        )
        .expect("request");
    req.wait();
    assert!(!req.is_failed(), "{:?}", req.error());
    assert_eq!(uf.count_components(), truth, "jt-cc streaming vs bfs");
}

#[test]
fn all_datasets_roundtrip_webgraph() {
    for d in Dataset::ALL {
        let g = d.generate(1, 42);
        let store = SimStore::new(DeviceKind::Dram);
        FormatKind::WebGraph.write_to_store(&g, &store, d.abbr());
        let accounts: Vec<IoAccount> = (0..4).map(|_| IoAccount::new()).collect();
        let loaded = FormatKind::WebGraph
            .load_full(&store, d.abbr(), ReadCtx::default(), &accounts)
            .unwrap_or_else(|e| panic!("{}: {e}", d.abbr()));
        assert_eq!(loaded, g, "{}", d.abbr());
    }
}

#[test]
fn compression_ratios_land_in_paper_regime() {
    // Table 1's ordering, plus absolute sanity: WebGraph stream well below
    // binary CSX; binary well below textual.
    let g = Dataset::Cw.generate(1, 42);
    let store = SimStore::new(DeviceKind::Dram);
    let mut bpe = std::collections::HashMap::new();
    for fk in FormatKind::ALL {
        let base = format!("t-{:?}", fk);
        fk.write_to_store(&g, &store, &base);
        bpe.insert(fk, fk.bits_per_edge(&g, &store, &base));
    }
    assert!(bpe[&FormatKind::TxtCoo] > 50.0, "textual COO ≈ 80 bits/edge");
    assert!(bpe[&FormatKind::BinCsx] > 30.0 && bpe[&FormatKind::BinCsx] < 45.0);
    assert!(
        bpe[&FormatKind::WebGraph] < bpe[&FormatKind::BinCsx] / 1.8,
        "WebGraph {:.1} vs BinCSX {:.1}",
        bpe[&FormatKind::WebGraph],
        bpe[&FormatKind::BinCsx]
    );
}

#[test]
fn xla_scan_engine_decodes_identically_to_native() {
    let dir = paragrapher::runtime::ArtifactSet::default_dir();
    let Ok(arts) = paragrapher::runtime::ArtifactSet::load(&dir) else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let g = generators::barabasi_albert(2000, 7, 13);
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    FormatKind::WebGraph.write_to_store(&g, &store, "g");

    let load_with = |opts: Options| {
        let graph = Paragrapher::init()
            .open_graph(Arc::clone(&store), "g", GraphType::CsxWg400, opts)
            .expect("open");
        graph.load_whole_graph().expect("load")
    };
    let native = load_with(Options::default());
    let xla = load_with(Options {
        scan: Arc::new(paragrapher::runtime::XlaScanEngine::new(arts)),
        ..Options::default()
    });
    assert_eq!(native, xla, "XLA-offloaded decode must equal native decode");
    assert_eq!(native.num_edges(), g.num_edges());
}
