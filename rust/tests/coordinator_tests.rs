//! Integration tests for the ParaGrapher coordinator: the public API,
//! sync/async equivalence, selective loading, the buffer protocol under
//! load, and failure injection.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use paragrapher::coordinator::{GraphType, Options, Paragrapher, VertexRange};
use paragrapher::formats::webgraph;
use paragrapher::graph::{generators, CsrGraph, VertexId};
use paragrapher::storage::sim::ReadCtx;
use paragrapher::storage::{DeviceKind, SimStore};

fn store_with(g: &CsrGraph, base: &str, device: DeviceKind) -> Arc<SimStore> {
    let store = Arc::new(SimStore::new(device));
    for (name, data) in webgraph::serialize(g, base) {
        store.put(&name, data);
    }
    store
}

fn open(
    store: &Arc<SimStore>,
    base: &str,
    opts: Options,
) -> paragrapher::coordinator::PgGraph {
    Paragrapher::init()
        .open_graph(Arc::clone(store), base, GraphType::CsxWg400, opts)
        .expect("open graph")
}

#[test]
fn open_reports_graph_shape() {
    let g = generators::rmat(8, 8, 1);
    let store = store_with(&g, "g", DeviceKind::Dram);
    let graph = open(&store, "g", Options::default());
    assert_eq!(graph.num_vertices(), g.num_vertices());
    assert_eq!(graph.num_edges(), g.num_edges());
    assert!(graph.sequential_seconds() > 0.0, "sequential open phase is accounted");
}

#[test]
fn whole_graph_sync_load_matches_original() {
    let g = generators::barabasi_albert(1500, 6, 3);
    let store = store_with(&g, "g", DeviceKind::Dram);
    for buffers in [1usize, 2, 4] {
        for buffer_edges in [1000u64, 1 << 14, 1 << 22] {
            let graph = open(
                &store,
                "g",
                Options { buffers, buffer_edges, ..Options::default() },
            );
            let block = graph.load_whole_graph().expect("load");
            assert_eq!(block.num_edges(), g.num_edges());
            for v in 0..g.num_vertices() {
                assert_eq!(
                    block.neighbors(v),
                    g.neighbors(v as VertexId),
                    "vertex {v} buffers={buffers} be={buffer_edges}"
                );
            }
        }
    }
}

#[test]
fn async_blocks_cover_range_exactly_once() {
    let g = generators::rmat(9, 8, 5);
    let store = store_with(&g, "g", DeviceKind::Dram);
    let graph = open(
        &store,
        "g",
        Options { buffers: 3, buffer_edges: 1 << 13, ..Options::default() },
    );
    let seen: Arc<Mutex<Vec<(usize, usize)>>> = Arc::new(Mutex::new(Vec::new()));
    let edges = Arc::new(AtomicU64::new(0));
    let (s2, e2) = (Arc::clone(&seen), Arc::clone(&edges));
    let range = VertexRange::new(10, g.num_vertices() - 10);
    let req = graph
        .csx_get_subgraph(
            range,
            Arc::new(move |blk| {
                s2.lock().unwrap().push((blk.start_vertex, blk.end_vertex));
                e2.fetch_add(blk.num_edges(), Ordering::SeqCst);
            }),
        )
        .expect("subgraph request");
    req.wait();
    assert!(req.is_complete());
    assert!(!req.is_failed(), "{:?}", req.error());
    // Blocks tile the range contiguously.
    let mut blocks = seen.lock().unwrap().clone();
    blocks.sort();
    assert_eq!(blocks.first().unwrap().0, range.start);
    assert_eq!(blocks.last().unwrap().1, range.end);
    for w in blocks.windows(2) {
        assert_eq!(w[0].1, w[1].0, "blocks must tile: {blocks:?}");
    }
    // Edge counts match the real subgraph.
    let expected: u64 =
        (range.start..range.end).map(|v| g.degree(v as VertexId)).sum();
    assert_eq!(edges.load(Ordering::SeqCst), expected);
    assert_eq!(req.edges_delivered(), expected);
}

#[test]
fn async_call_returns_before_completion() {
    let g = generators::barabasi_albert(4000, 8, 9);
    // HDD: slow enough that loading takes real (virtual) work; the call
    // itself must return immediately.
    let store = store_with(&g, "g", DeviceKind::Dram);
    let graph = open(
        &store,
        "g",
        Options { buffers: 1, buffer_edges: 2000, ..Options::default() },
    );
    let t0 = std::time::Instant::now();
    let req = graph
        .csx_get_subgraph(VertexRange::new(0, g.num_vertices()), Arc::new(|_| {}))
        .expect("request");
    let returned_in = t0.elapsed();
    assert!(
        returned_in.as_millis() < 500,
        "async call should return quickly, took {returned_in:?}"
    );
    assert!(req.total_blocks() > 1);
    req.wait();
    assert!(req.is_complete());
}

#[test]
fn selective_subrange_loads_only_that_subgraph() {
    let g = generators::barabasi_albert(3000, 6, 11);
    let store = store_with(&g, "g", DeviceKind::Dram);
    let graph = open(&store, "g", Options::default());
    let block = graph
        .csx_get_subgraph_sync(VertexRange::new(100, 140))
        .expect("sync subgraph");
    assert_eq!(block.num_vertices(), 40);
    for (i, v) in (100..140).enumerate() {
        assert_eq!(block.neighbors(i), g.neighbors(v as VertexId), "vertex {v}");
    }
}

#[test]
fn coo_edge_granular_requests_trim_correctly() {
    let g = generators::rmat(8, 6, 13);
    let store = store_with(&g, "g", DeviceKind::Dram);
    let graph = open(&store, "g", Options::default());
    let m = g.num_edges();
    // Collect all (src, dst) via coo_get_edges over a strict edge range.
    let (lo, hi) = (m / 5, m - m / 3);
    let collected: Arc<Mutex<Vec<(VertexId, VertexId)>>> = Arc::new(Mutex::new(Vec::new()));
    let c2 = Arc::clone(&collected);
    let req = graph
        .coo_get_edges(
            lo,
            hi,
            Arc::new(move |blk| {
                c2.lock().unwrap().extend(blk.iter_edges());
            }),
        )
        .expect("coo request");
    req.wait();
    assert!(!req.is_failed(), "{:?}", req.error());
    let mut got = collected.lock().unwrap().clone();
    got.sort();
    let mut expected: Vec<(VertexId, VertexId)> = g
        .iter_edges()
        .enumerate()
        .filter(|(i, _)| (*i as u64) >= lo && (*i as u64) < hi)
        .map(|(_, e)| e)
        .collect();
    expected.sort();
    assert_eq!(got.len(), expected.len());
    assert_eq!(got, expected);
}

#[test]
fn block_request_path_is_zero_copy() {
    // The tentpole invariant: with the default single-worker decode, every
    // delivered payload byte lands in the buffer straight from the decoder
    // — zero post-decode copies — and the counters prove it.
    let g = generators::barabasi_albert(2500, 7, 19);
    let store = store_with(&g, "g", DeviceKind::Dram);
    let graph = open(
        &store,
        "g",
        Options { buffers: 3, buffer_edges: 4000, ..Options::default() },
    );
    let block = graph.load_whole_graph().expect("load");
    assert_eq!(block.num_edges(), g.num_edges());
    assert_eq!(
        graph.delivery_copy_bytes(),
        0,
        "single-worker block delivery must not copy after decode"
    );
    // Offsets (8 B each) + edges (4 B each) at minimum were delivered
    // copy-free; the whole graph flowed through.
    let floor = g.num_edges() * 4;
    assert!(
        graph.copy_bytes_avoided() >= floor,
        "copy_bytes_avoided {} must cover at least the edge payload {floor}",
        graph.copy_bytes_avoided()
    );
    assert!(graph.delivery_throughput() > 0.0, "delivery throughput is measured");

    // Fan-out decode: the vertex-order stitch is the one permitted copy —
    // counted, and never larger than the payload it assembles.
    let graph2 = open(
        &store,
        "g",
        Options { buffers: 2, decode_workers: 4, buffer_edges: 1 << 13, ..Options::default() },
    );
    let block2 = graph2.load_whole_graph().expect("load");
    assert_eq!(block2.num_edges(), g.num_edges());
    assert!(
        graph2.delivery_copy_bytes() > 0,
        "multi-worker fan-out stitches through one counted copy"
    );
}

#[test]
fn sink_decode_failure_recycles_buffers_and_pool_survives() {
    // A sink-backed decode that fails mid-block must return its buffer to
    // C_IDLE (never wedging the pool), and the same graph handle must
    // serve later requests once the stream is healthy again.
    let g = generators::barabasi_albert(3000, 6, 53);
    let store = store_with(&g, "g", DeviceKind::Dram);
    let good: Vec<u8> = webgraph::serialize(&g, "g")
        .into_iter()
        .find(|(name, _)| name.ends_with(".graph"))
        .map(|(_, data)| data)
        .expect("graph stream");
    let buffers = 3;
    let graph = open(
        &store,
        "g",
        Options { buffers, buffer_edges: 1500, ..Options::default() },
    );
    assert_eq!(graph.idle_buffers(), buffers);
    // Truncate the stream under the opened graph: early blocks decode,
    // later blocks fail mid-request.
    store.put("g.graph", good[..good.len() / 8].to_vec());
    let result = graph.load_whole_graph();
    assert!(result.is_err(), "truncated stream must fail the load");
    assert_eq!(
        graph.idle_buffers(),
        buffers,
        "every buffer must return to C_IDLE after a failed sink decode"
    );
    // Heal the stream: the pool must not be wedged.
    store.put("g.graph", good);
    store.drop_cache();
    let block = graph.load_whole_graph().expect("pool must survive the failure");
    assert_eq!(block.num_edges(), g.num_edges());
    for v in 0..g.num_vertices() {
        assert_eq!(block.neighbors(v), g.neighbors(v as VertexId), "vertex {v}");
    }
    assert_eq!(graph.idle_buffers(), buffers);
}

#[test]
fn coo_trimmed_views_deliver_weights() {
    // COO trim hands out borrowed views now — including the weight lane,
    // which the copy-based trim used to drop.
    let mut edges = Vec::new();
    let mut wv = 0.25f32;
    for v in 0..300u32 {
        for d in 0..(v % 5) {
            edges.push((v, (v * 3 + d) % 300, wv));
            wv = (wv * 1.3).fract() + 0.05;
        }
    }
    let g = CsrGraph::from_weighted_edges(300, &edges);
    let store = store_with(&g, "w", DeviceKind::Dram);
    let graph = Paragrapher::init()
        .open_graph(
            Arc::clone(&store),
            "w",
            GraphType::CsxWg404,
            Options { buffer_edges: 97, ..Options::default() },
        )
        .expect("open weighted");
    let m = g.num_edges();
    let (lo, hi) = (m / 4, m - m / 6);
    type Triple = (VertexId, VertexId, u32);
    let collected: Arc<Mutex<Vec<Triple>>> = Arc::new(Mutex::new(Vec::new()));
    let c2 = Arc::clone(&collected);
    let req = graph
        .coo_get_edges(
            lo,
            hi,
            Arc::new(move |blk| {
                let w = blk.weights.expect("trimmed views keep the weight lane");
                assert_eq!(w.len() as u64, blk.num_edges(), "weights align with edges");
                let mut out = c2.lock().unwrap();
                for ((s, d), &wt) in blk.iter_edges().zip(w.iter()) {
                    out.push((s, d, wt.to_bits()));
                }
            }),
        )
        .expect("coo request");
    req.wait();
    assert!(!req.is_failed(), "{:?}", req.error());
    let mut got = collected.lock().unwrap().clone();
    got.sort();
    let mut expected: Vec<Triple> = g
        .iter_edges()
        .zip(g.weights.iter())
        .enumerate()
        .filter(|(i, _)| (*i as u64) >= lo && (*i as u64) < hi)
        .map(|(_, ((s, d), &w))| (s, d, w.to_bits()))
        .collect();
    expected.sort();
    assert_eq!(got, expected);
    assert!(graph.copy_bytes_avoided() > 0, "trim views are counted as avoided copies");
}

#[test]
fn weighted_fan_out_decode_accounts_the_weights_phase() {
    // decode_workers > 1 on a weighted graph: the weights sidecar read is
    // its own modeled phase (added to the chunk-worker max), and the
    // delivered weights stay exact.
    let mut edges = Vec::new();
    for v in 0..800u32 {
        for d in 0..(v % 9) {
            edges.push((v, (v + 7 * d + 1) % 800, (v as f32) * 0.5 + d as f32));
        }
    }
    let g = CsrGraph::from_weighted_edges(800, &edges);
    let store = store_with(&g, "w", DeviceKind::Hdd);
    let graph = Paragrapher::init()
        .open_graph(
            Arc::clone(&store),
            "w",
            GraphType::CsxWg404,
            Options { decode_workers: 3, buffer_edges: 1 << 11, ..Options::default() },
        )
        .expect("open weighted");
    type WeightPart = (u64, Vec<f32>);
    let got: Arc<Mutex<Vec<WeightPart>>> = Arc::new(Mutex::new(Vec::new()));
    let g2 = Arc::clone(&got);
    let req = graph
        .csx_get_subgraph(
            VertexRange::new(0, 800),
            Arc::new(move |blk| {
                let w = blk.weights.expect("weights present");
                g2.lock().unwrap().push((blk.start_edge, w.to_vec()));
            }),
        )
        .expect("request");
    req.wait();
    assert!(!req.is_failed(), "{:?}", req.error());
    let mut parts = got.lock().unwrap().clone();
    parts.sort_by_key(|(se, _)| *se);
    let all: Vec<f32> = parts.into_iter().flat_map(|(_, w)| w).collect();
    assert_eq!(all, g.weights);
    assert!(graph.decode_seconds() > 0.0, "weights phase lands in the modeled time");
}

#[test]
fn csx_get_offsets_matches_graph() {
    let g = generators::rmat(7, 8, 17);
    let store = store_with(&g, "g", DeviceKind::Dram);
    let graph = open(&store, "g", Options::default());
    let offs = graph.csx_get_offsets(0, g.num_vertices()).expect("offsets");
    assert_eq!(offs, g.offsets);
    let slice = graph.csx_get_offsets(5, 10).expect("offsets slice");
    assert_eq!(slice, g.offsets[5..=10].to_vec());
    assert!(graph.csx_get_offsets(10, 5).is_err());
    assert!(graph.csx_get_vertex_weights(0, 5).is_err(), "no vertex weights (Table 2)");
}

#[test]
fn weighted_graph_delivers_weights() {
    let mut edges = Vec::new();
    let mut rngv = 0.5f32;
    for v in 0..200u32 {
        for d in 0..(v % 7) {
            edges.push((v, (v + d + 1) % 200, rngv));
            rngv = (rngv * 1.7).fract() + 0.1;
        }
    }
    let g = CsrGraph::from_weighted_edges(200, &edges);
    let store = store_with(&g, "w", DeviceKind::Dram);
    let graph = Paragrapher::init()
        .open_graph(Arc::clone(&store), "w", GraphType::CsxWg404, Options::default())
        .expect("open weighted");
    let got: Arc<Mutex<Vec<f32>>> = Arc::new(Mutex::new(Vec::new()));
    let g2 = Arc::clone(&got);
    let req = graph
        .csx_get_subgraph(
            VertexRange::new(0, 200),
            Arc::new(move |blk| {
                let w = blk.weights.expect("weights present for WG404");
                g2.lock().unwrap().extend_from_slice(w);
            }),
        )
        .expect("request");
    req.wait();
    assert!(!req.is_failed(), "{:?}", req.error());
    assert_eq!(*got.lock().unwrap(), g.weights);
}

#[test]
fn opening_unweighted_as_wg404_fails() {
    let g = generators::rmat(6, 4, 19);
    let store = store_with(&g, "g", DeviceKind::Dram);
    let result = Paragrapher::init().open_graph(
        Arc::clone(&store),
        "g",
        GraphType::CsxWg404,
        Options::default(),
    );
    assert!(result.is_err());
}

#[test]
fn callback_panic_fails_request_without_hanging() {
    let g = generators::rmat(8, 6, 23);
    let store = store_with(&g, "g", DeviceKind::Dram);
    let graph = open(
        &store,
        "g",
        // Small buffers: force multiple blocks so a non-first block panics.
        Options { buffers: 2, buffer_edges: 200, ..Options::default() },
    );
    let req = graph
        .csx_get_subgraph(
            VertexRange::new(0, g.num_vertices()),
            Arc::new(|blk| {
                if blk.start_vertex > 0 {
                    panic!("injected callback failure");
                }
            }),
        )
        .expect("request");
    req.wait(); // must terminate
    assert!(req.is_failed());
    assert!(req.error().unwrap().contains("panicked"));
}

#[test]
fn corrupt_graph_file_fails_cleanly() {
    let g = generators::barabasi_albert(800, 5, 29);
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    for (name, mut data) in webgraph::serialize(&g, "g") {
        if name.ends_with(".graph") {
            let n = data.len();
            for b in data.iter_mut().skip(n / 4) {
                *b = 0xAA;
            }
        }
        store.put(&name, data);
    }
    let graph = open(&store, "g", Options::default());
    let result = graph.load_whole_graph();
    assert!(result.is_err(), "corrupted stream must fail the blocking load");
}

#[test]
fn cancellation_stops_unscheduled_blocks() {
    let g = generators::barabasi_albert(5000, 8, 31);
    let store = store_with(&g, "g", DeviceKind::Dram);
    let graph = open(
        &store,
        "g",
        Options { buffers: 1, buffer_edges: 1000, ..Options::default() },
    );
    let calls = Arc::new(AtomicU64::new(0));
    let c2 = Arc::clone(&calls);
    let req = graph
        .csx_get_subgraph(
            VertexRange::new(0, g.num_vertices()),
            Arc::new(move |_| {
                c2.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }),
        )
        .expect("request");
    req.cancel();
    req.wait(); // must complete (skipped blocks count as done)
    assert!(req.is_complete());
    assert!(
        calls.load(Ordering::SeqCst) < req.total_blocks(),
        "cancel should skip most blocks"
    );
}

#[test]
fn poll_interval_is_dead_the_condvar_schedules() {
    // `Options::poll_interval` is deprecated and ignored: set it to a
    // pathological 60 s and stream many blocks through a single buffer. A
    // poll-driven request manager would sleep ~60 s per buffer wait; the
    // condvar-driven one finishes in milliseconds. The generous bound keeps
    // slow CI machines from flaking while still being ~2 orders of
    // magnitude under one poll sleep.
    let g = generators::barabasi_albert(3000, 6, 7);
    let store = store_with(&g, "g", DeviceKind::Dram);
    #[allow(deprecated)]
    let opts = Options {
        buffers: 1,
        buffer_edges: 1000,
        poll_interval: std::time::Duration::from_secs(60),
        ..Options::default()
    };
    let graph = open(&store, "g", opts);
    let t0 = std::time::Instant::now();
    let block = graph.load_whole_graph().expect("load");
    assert_eq!(block.num_edges(), g.num_edges());
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "request manager slept on the deprecated poll_interval: took {:?}",
        t0.elapsed()
    );
}

#[test]
fn decode_workers_fan_out_is_equivalent_and_accounted() {
    let g = generators::rmat(9, 8, 43);
    let store = store_with(&g, "g", DeviceKind::Dram);
    let mut baseline = None;
    for decode_workers in [1usize, 4] {
        let graph = open(
            &store,
            "g",
            Options { decode_workers, buffer_edges: 1 << 13, ..Options::default() },
        );
        let block = graph.load_whole_graph().expect("load");
        for v in 0..g.num_vertices() {
            assert_eq!(
                block.neighbors(v),
                g.neighbors(v as VertexId),
                "vertex {v} decode_workers={decode_workers}"
            );
        }
        // The per-chunk virtual clocks were threaded through (§3 model).
        assert!(
            graph.decode_seconds() > 0.0,
            "decode_workers={decode_workers} must account modeled decode time"
        );
        let edges = block.num_edges();
        match baseline {
            None => baseline = Some(edges),
            Some(b) => assert_eq!(edges, b, "fan-out must not change results"),
        }
    }
}

#[test]
fn release_restores_resources() {
    let g = generators::rmat(7, 6, 37);
    let store = store_with(&g, "g", DeviceKind::Dram);
    let graph = open(&store, "g", Options::default());
    let _ = graph.load_whole_graph().expect("load");
    let (hits_before, _) = store.cache_stats();
    assert!(hits_before > 0 || store.device_bytes() > 0);
    Paragrapher::init().release_graph(graph);
    // After release the simulated OS cache is dropped (§4.1 discipline):
    // a fresh read misses again.
    let acct = paragrapher::storage::IoAccount::new();
    let f = store.open("g.graph").unwrap();
    f.read(0, 1 << 12, ReadCtx::default(), &acct);
    assert!(acct.bytes_read() > 0, "cold read after release");
}

#[test]
fn progress_queries_are_monotone() {
    let g = generators::barabasi_albert(3000, 6, 41);
    let store = store_with(&g, "g", DeviceKind::Dram);
    let graph = open(
        &store,
        "g",
        Options { buffers: 2, buffer_edges: 5000, ..Options::default() },
    );
    let req = graph
        .csx_get_subgraph(VertexRange::new(0, g.num_vertices()), Arc::new(|_| {}))
        .expect("request");
    let mut last = 0;
    loop {
        let done = req.blocks_done();
        assert!(done >= last);
        last = done;
        if req.is_complete() {
            break;
        }
        std::thread::yield_now();
    }
    assert_eq!(req.edges_delivered(), g.num_edges());
}
