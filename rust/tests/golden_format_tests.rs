//! Golden-file format tests: a tiny graph's exact `.graph` / `.offsets` /
//! `.properties` bytes are checked in under `golden/` (generated and
//! cross-verified by `golden/gen_golden.py`), and re-encoding the same
//! graph must byte-compare equal. Silent format drift — which would
//! invalidate cross-PR benchmark comparisons and break on-disk
//! compatibility — fails here instead of going unnoticed.
//!
//! The fixture exercises every encoder technique: an interval run
//! (vertices 0 and 7), pure residuals (vertex 1), a partial copy with
//! explicit copy/skip blocks (vertex 2), a single residual (vertex 3), an
//! empty list (vertex 4), and a whole-list reference (vertex 6 → 5).

use std::sync::Arc;

use paragrapher::coordinator::{GraphType, Options, Paragrapher, VertexRange};
use paragrapher::formats::webgraph;
use paragrapher::graph::CsrGraph;
use paragrapher::storage::sim::ReadCtx;
use paragrapher::storage::{DeviceKind, IoAccount, SimStore};

const GOLDEN_GRAPH: &[u8] = include_bytes!("golden/tiny.graph");
const GOLDEN_OFFSETS: &[u8] = include_bytes!("golden/tiny.offsets");
const GOLDEN_PROPERTIES: &[u8] = include_bytes!("golden/tiny.properties");

/// Keep in sync with `ADJ` in `golden/gen_golden.py`.
fn tiny_graph() -> CsrGraph {
    let adj: [&[u32]; 8] = [
        &[1, 2, 3, 4],
        &[0, 2, 4, 6],
        &[1, 3, 4],
        &[5],
        &[],
        &[0, 2, 3, 4, 7],
        &[0, 2, 3, 4, 7],
        &[0, 1, 2, 3, 4, 5, 6],
    ];
    let mut edges = Vec::new();
    for (v, list) in adj.iter().enumerate() {
        for &d in list.iter() {
            edges.push((v as u32, d));
        }
    }
    CsrGraph::from_edges(8, &edges)
}

fn fixture_files() -> [(&'static str, &'static [u8]); 3] {
    [
        ("tiny.graph", GOLDEN_GRAPH),
        ("tiny.offsets", GOLDEN_OFFSETS),
        ("tiny.properties", GOLDEN_PROPERTIES),
    ]
}

#[test]
fn encoder_output_matches_golden_bytes() {
    let g = tiny_graph();
    let files = webgraph::serialize(&g, "tiny");
    assert_eq!(files.len(), 3);
    for (name, data) in &files {
        let expected = fixture_files()
            .iter()
            .find(|(n, _)| name.ends_with(n))
            .unwrap_or_else(|| panic!("unexpected file {name}"))
            .1;
        assert_eq!(
            data.as_slice(),
            expected,
            "{name} drifted from the golden fixture.\n  got:      {}\n  expected: {}\n\
             If the change is intentional, regenerate with \
             `python3 rust/tests/golden/gen_golden.py` and say so in the PR.",
            hex(data),
            hex(expected)
        );
    }
}

#[test]
fn golden_fixture_decodes_to_the_tiny_graph() {
    let g = tiny_graph();
    let store = SimStore::new(DeviceKind::Dram);
    for (name, data) in fixture_files() {
        store.put(name, data.to_vec());
    }
    let accounts: Vec<IoAccount> = (0..2).map(|_| IoAccount::new()).collect();
    let loaded = webgraph::load_full(&store, "tiny", ReadCtx::default(), &accounts).unwrap();
    assert_eq!(loaded, g, "fixture bytes must decode to the reference graph");

    // Per-vertex random access over the fixture too.
    let acct = IoAccount::new();
    let meta = webgraph::read_meta(&store, "tiny", ReadCtx::default(), &acct).unwrap();
    let offs = webgraph::read_offsets(&store, "tiny", ReadCtx::default(), &acct).unwrap();
    let dec =
        webgraph::Decoder::open(&store, "tiny", &meta, &offs, ReadCtx::default(), &acct).unwrap();
    for v in 0..8usize {
        assert_eq!(dec.decode_vertex(v, &acct).unwrap(), g.neighbors(v as u32), "vertex {v}");
    }
}

#[test]
fn reencoding_the_decoded_fixture_is_idempotent() {
    // decode(fixture) -> encode must reproduce the fixture exactly: catches
    // drift in either direction (decoder *or* encoder).
    let store = SimStore::new(DeviceKind::Dram);
    for (name, data) in fixture_files() {
        store.put(name, data.to_vec());
    }
    let accounts = [IoAccount::new()];
    let decoded = webgraph::load_full(&store, "tiny", ReadCtx::default(), &accounts).unwrap();
    for (name, data) in webgraph::serialize(&decoded, "tiny") {
        let expected = fixture_files()
            .iter()
            .find(|(n, _)| name.ends_with(n))
            .unwrap()
            .1;
        assert_eq!(data.as_slice(), expected, "{name} not idempotent");
    }
}

#[test]
fn golden_fixture_loads_through_the_coordinator() {
    let g = tiny_graph();
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    for (name, data) in fixture_files() {
        store.put(name, data.to_vec());
    }
    let graph = Paragrapher::init()
        .open_graph(Arc::clone(&store), "tiny", GraphType::CsxWg400, Options::default())
        .unwrap();
    let block = graph.csx_get_subgraph_sync(VertexRange::new(0, 8)).unwrap();
    for v in 0..8usize {
        assert_eq!(block.neighbors(v), g.neighbors(v as u32), "vertex {v}");
    }
    assert_eq!(graph.csx_get_offsets(0, 8).unwrap(), g.offsets);
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}
