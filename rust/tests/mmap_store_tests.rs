//! Integration tests of the mmap-backed real-file [`GraphStore`]: golden
//! round-trip against the in-memory store (identical decode output and
//! stats counters), `MmapDirect` rejection at open, bounded residency
//! under a small page-cache budget, multi-worker zero-copy delivery on
//! real files, and a small-scale out-of-core load verified against the
//! regenerating streaming oracle.

use std::sync::Arc;

use paragrapher::coordinator::{GraphType, Options, Paragrapher};
use paragrapher::formats::webgraph::{self, DecodeSink, Decoder, WgParams};
use paragrapher::graph::{generators, VertexId};
use paragrapher::storage::{DeviceKind, GraphStore, IoAccount, ReadCtx, ReadMethod, SimStore};

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("pg_mmap_{}_{}", tag, std::process::id()));
    // A fresh directory per run: stale fixtures from a crashed run must not
    // leak into this one.
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Serialize `g` both into an in-memory store and as real files under a
/// fresh temp dir opened through the mmap backend.
fn both_stores(
    g: &paragrapher::graph::CsrGraph,
    tag: &str,
) -> (Arc<SimStore>, Arc<GraphStore>, std::path::PathBuf) {
    let mem = Arc::new(SimStore::new(DeviceKind::Dram));
    let dir = temp_dir(tag);
    for (name, data) in webgraph::serialize(g, "g") {
        mem.put(&name, data.clone());
        std::fs::write(dir.join(&name), data).unwrap();
    }
    let mapped = Arc::new(GraphStore::open_dir(&dir, DeviceKind::Dram).unwrap());
    (mem, mapped, dir)
}

#[test]
fn golden_fixture_roundtrip_matches_sim_store() {
    let g = generators::barabasi_albert(1200, 6, 9);
    let (mem, mapped, dir) = both_stores(&g, "golden");
    let pg = Paragrapher::init();
    let opts = || Options {
        buffer_edges: 2000,
        read_ctx: ReadCtx { method: ReadMethod::Mmap, ..ReadCtx::default() },
        ..Options::default()
    };
    let via_mem = pg.open_graph(Arc::clone(&mem), "g", GraphType::CsxWg400, opts()).unwrap();
    let via_map = pg.open_graph(Arc::clone(&mapped), "g", GraphType::CsxWg400, opts()).unwrap();
    let block_mem = via_mem.load_whole_graph().unwrap();
    let block_map = via_map.load_whole_graph().unwrap();
    assert_eq!(block_mem, block_map, "decode output must not depend on the backing");
    assert_eq!(block_map.num_edges(), g.num_edges());
    // Count-type stats counters must be identical across backings (the
    // time-type ones measure real CPU and legitimately differ).
    use std::sync::atomic::Ordering::Relaxed;
    let (sm, sp) = (via_mem.stats(), via_map.stats());
    assert_eq!(sm.blocks_decoded.load(Relaxed), sp.blocks_decoded.load(Relaxed));
    assert_eq!(sm.edges_decoded.load(Relaxed), sp.edges_decoded.load(Relaxed));
    assert_eq!(sm.requests_issued.load(Relaxed), sp.requests_issued.load(Relaxed));
    assert_eq!(sm.copy_bytes_avoided.load(Relaxed), sp.copy_bytes_avoided.load(Relaxed));
    assert_eq!(sp.delivery_copy_bytes.load(Relaxed), 0, "zero-copy on the mmap store");
    assert_eq!(sm.delivery_copy_bytes.load(Relaxed), 0, "zero-copy on the sim store");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mmap_direct_is_rejected_at_open() {
    let g = generators::barabasi_albert(300, 4, 2);
    let store = Arc::new(SimStore::new(DeviceKind::Ssd));
    for (name, data) in webgraph::serialize(&g, "g") {
        store.put(&name, data);
    }
    let pg = Paragrapher::init();
    let opts = Options {
        read_ctx: ReadCtx { method: ReadMethod::MmapDirect, ..ReadCtx::default() },
        ..Options::default()
    };
    let err = pg.open_graph(store, "g", GraphType::CsxWg400, opts).unwrap_err();
    assert!(
        err.to_string().contains("MmapDirect"),
        "rejection must name the offending method: {err}"
    );
}

#[test]
fn budgeted_mmap_decode_bounds_model_residency() {
    let g = generators::barabasi_albert(6000, 8, 5);
    let (_, _, dir) = both_stores(&g, "budget");
    let budget = 32u64 << 10; // 2 cache pages — far below the fixture
    let graph_bytes = std::fs::metadata(dir.join("g.graph")).unwrap().len();
    assert!(graph_bytes > budget, "fixture ({graph_bytes} B) must exceed the {budget} B budget");
    let store = GraphStore::open_dir_with(&dir, DeviceKind::Ssd.model(), budget).unwrap();
    let acct = IoAccount::new();
    let ctx = ReadCtx { method: ReadMethod::Mmap, ..ReadCtx::default() };
    let meta = webgraph::read_meta(&store, "g", ctx, &acct).unwrap();
    let offsets = webgraph::read_offsets(&store, "g", ctx, &acct).unwrap();
    let dec = Decoder::open(&store, "g", &meta, &offsets, ctx, &acct).unwrap();
    let n = g.num_vertices();
    let mut off_buf = Vec::new();
    let mut edge_buf: Vec<VertexId> = Vec::new();
    let mut vs = 0usize;
    while vs < n {
        let ve = (vs + 500).min(n);
        let mut sink = DecodeSink::new(&mut off_buf, &mut edge_buf);
        dec.decode_range_sink(vs, ve, &acct, &paragrapher::runtime::NativeScan, &mut sink)
            .unwrap();
        for v in vs..ve {
            let (a, b) = (off_buf[v - vs] as usize, off_buf[v - vs + 1] as usize);
            assert_eq!(&edge_buf[a..b], g.neighbors(v as VertexId), "vertex {v}");
        }
        assert!(
            store.cache_resident_bytes() <= budget,
            "modeled residency {} exceeds the {} budget",
            store.cache_resident_bytes(),
            budget
        );
        vs = ve;
    }
    assert!(acct.io_seconds() > 0.0, "cold pages must be billed to the device model");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn multi_worker_delivery_on_real_files_is_zero_copy() {
    let g = generators::web_locality(3000, 8, 0.9, 0.6, 4);
    let (_, mapped, dir) = both_stores(&g, "workers");
    let pg = Paragrapher::init();
    let opts = Options {
        buffers: 2,
        decode_workers: 3,
        buffer_edges: 4000,
        read_ctx: ReadCtx { method: ReadMethod::Mmap, ..ReadCtx::default() },
        ..Options::default()
    };
    let graph = pg.open_graph(Arc::clone(&mapped), "g", GraphType::CsxWg400, opts).unwrap();
    let block = graph.load_whole_graph().unwrap();
    assert_eq!(block.num_edges(), g.num_edges());
    assert_eq!(
        graph.delivery_copy_bytes(),
        0,
        "pre-partitioned fan-out must write the sink in place"
    );
    assert!(graph.copy_bytes_avoided() > 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn small_out_of_core_load_matches_streaming_oracle() {
    let (n, deg, seed) = (3000usize, 10usize, 11u64);
    let dir = temp_dir("ooc");
    let streamed = webgraph::write_stream_to_dir(&dir, "ooc", n, WgParams::default(), |v, out| {
        generators::synthetic_successors(v, n, deg, seed, out)
    })
    .unwrap();
    let budget = 32u64 << 10;
    let store = GraphStore::open_dir_with(&dir, DeviceKind::Ssd.model(), budget).unwrap();
    let acct = IoAccount::new();
    let ctx = ReadCtx { method: ReadMethod::Mmap, ..ReadCtx::default() };
    let meta = webgraph::read_meta(&store, "ooc", ctx, &acct).unwrap();
    let offsets = webgraph::read_offsets(&store, "ooc", ctx, &acct).unwrap();
    let dec = Decoder::open(&store, "ooc", &meta, &offsets, ctx, &acct).unwrap();
    let accounts: Vec<IoAccount> = (0..2).map(|_| IoAccount::new()).collect();
    let mut off_buf = Vec::new();
    let mut edge_buf: Vec<VertexId> = Vec::new();
    let mut oracle: Vec<VertexId> = Vec::new();
    let mut stitched = 0u64;
    let mut edges_seen = 0u64;
    let mut vs = 0usize;
    while vs < n {
        let ve = (vs + 700).min(n);
        let mut sink = DecodeSink::new(&mut off_buf, &mut edge_buf);
        stitched += dec
            .decode_range_parallel_sink(
                vs,
                ve,
                &accounts,
                &paragrapher::runtime::NativeScan,
                None,
                &mut sink,
            )
            .unwrap();
        edges_seen += *off_buf.last().unwrap();
        for v in vs..ve {
            let (a, b) = (off_buf[v - vs] as usize, off_buf[v - vs + 1] as usize);
            generators::synthetic_successors(v, n, deg, seed, &mut oracle);
            assert_eq!(&edge_buf[a..b], &oracle[..], "vertex {v}");
        }
        assert!(store.cache_resident_bytes() <= budget, "residency exceeds budget");
        vs = ve;
    }
    assert_eq!(edges_seen, streamed.num_edges);
    assert_eq!(stitched, 0, "chunk fan-out must write the sink in place");
    std::fs::remove_dir_all(&dir).ok();
}
