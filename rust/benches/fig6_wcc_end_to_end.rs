//! Fig. 6 — end-to-end Weakly-Connected Components time (seconds):
//! ParaGrapher (WebGraph + streaming JT-CC) vs GAPBS-style baselines
//! (Txt COO / Bin CSX full load + Afforest) on HDD, SSD and NAS.
//!
//! The paper's shape: ParaGrapher wins end-to-end by up to 5.2× because
//! loading dominates and compressed partial loading overlaps processing;
//! on SSD with Bin CSX the gap narrows (decode-bound).

use std::time::Instant;

use paragrapher::algorithms::afforest::afforest;
use paragrapher::algorithms::jtcc::JtUnionFind;
use paragrapher::bench::workloads::{
    full_load_memory_bytes, modeled_full_load, modeled_paragrapher_load,
};
use paragrapher::bench::Harness;
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::runtime::NativeScan;
use paragrapher::storage::{DeviceKind, IoAccount, SimStore};
use paragrapher::storage::sim::ReadCtx;

const THREADS: usize = 8;
const MEMORY_BUDGET: u64 = 4 << 20;

fn main() {
    let mut h = Harness::new("fig6_wcc_end_to_end");
    let mut best_speedup = 0.0f64;

    for dataset in Dataset::ALL {
        let g = dataset.generate(1, 42);
        // Ground truth once per dataset.
        let truth = paragrapher::algorithms::count_components(
            &paragrapher::algorithms::bfs::wcc_by_bfs(&g),
        );
        for device in [DeviceKind::Hdd, DeviceKind::Ssd, DeviceKind::Nas] {
            let store = SimStore::new_scaled(device);
            let mut bin_e2e: Option<f64> = None;
            for format in [FormatKind::TxtCoo, FormatKind::BinCsx, FormatKind::WebGraph] {
                let base = format!("{}-{:?}", dataset.abbr(), format);
                format.write_to_store(&g, &store, &base);
                let case = format!("{}/{}/{}", dataset.abbr(), device.name(), format.name());
                if format != FormatKind::WebGraph
                    && full_load_memory_bytes(g.num_vertices(), g.num_edges())
                        > MEMORY_BUDGET
                {
                    h.report(&case, "e2e_s", -1.0);
                    continue;
                }
                let e2e = match format {
                    FormatKind::WebGraph => {
                        // ParaGrapher: modeled load + JT-CC streamed per
                        // block (CPU measured inside the load accounts via
                        // a decode+union pass: here approximated as decode
                        // model + measured union time overlapped).
                        let buffer =
                            (g.num_edges() / (4 * THREADS as u64)).max(8 << 10);
                        let r = modeled_paragrapher_load(
                            &store, &base, THREADS, buffer, &NativeScan, 100e-6, None,
                        )
                        .expect("pg load");
                        let uf = JtUnionFind::new(g.num_vertices(), 7);
                        let t0 = Instant::now();
                        for (s, d) in g.iter_edges() {
                            uf.union(s, d);
                        }
                        let union_cpu = t0.elapsed().as_secs_f64();
                        assert_eq!(uf.count_components(), truth);
                        // Union work spreads over THREADS workers and
                        // overlaps I/O; the slower of the two phases
                        // dominates, plus the sequential open.
                        r.sequential_seconds
                            + r.parallel_seconds.max(union_cpu / THREADS as f64)
                    }
                    _ => {
                        // Baseline: full cold load, then Afforest on the
                        // in-memory graph.
                        let m = modeled_full_load(&store, &base, format, THREADS)
                            .expect("baseline load");
                        store.drop_cache();
                        let ctx = ReadCtx { threads: THREADS, ..ReadCtx::default() };
                        let accounts: Vec<IoAccount> =
                            (0..THREADS).map(|_| IoAccount::new()).collect();
                        let loaded = format
                            .load_full(&store, &base, ctx, &accounts)
                            .expect("reload");
                        let t0 = Instant::now();
                        let labels = afforest(&loaded, 7);
                        let algo = t0.elapsed().as_secs_f64() / THREADS as f64;
                        assert_eq!(
                            paragrapher::algorithms::count_components(&labels),
                            truth
                        );
                        m.elapsed + algo
                    }
                };
                h.report(&case, "e2e_s", e2e);
                if format == FormatKind::BinCsx {
                    bin_e2e = Some(e2e);
                }
                if format == FormatKind::WebGraph {
                    if let Some(b) = bin_e2e {
                        let speedup = b / e2e;
                        h.report(
                            &format!("{}/{}/e2e-speedup", dataset.abbr(), device.name()),
                            "x",
                            speedup,
                        );
                        best_speedup = best_speedup.max(speedup);
                    }
                }
            }
        }
    }
    h.note(&format!(
        "max end-to-end WCC speedup vs Bin CSX: {best_speedup:.2}x (paper: up to 5.2x)"
    ));
    assert!(best_speedup > 1.0, "ParaGrapher must win somewhere end-to-end");
    h.finish();
}
