//! Fig. 5 — loading throughput (Million Edges/s) of ParaGrapher (WebGraph)
//! vs the GAPBS-style baselines (Textual COO, Binary CSX) on HDD, SSD and
//! NAS, for the whole dataset suite.
//!
//! Paper shapes to reproduce:
//! * HDD: base binary-CSX throughput ≈ 40 ME/s at σ=160 MB/s with 4 B/edge;
//!   ParaGrapher reaches ~3.2× that (≈ 129 ME/s) thanks to compression.
//! * SSD: binary CSX ≈ 504 ME/s (single-stream-bound); ParaGrapher is
//!   decode-bound well below σ·r (the §3 envelope's d-limb).
//! * NAS: ParaGrapher ≈ 7.3× binary CSX (link-bound, ratio ≈ r).
//! * Graphs too large for memory: baselines report "-1" (OOM); ParaGrapher
//!   still loads via partial blocks.

use paragrapher::bench::workloads::{
    full_load_memory_bytes, modeled_full_load, modeled_paragrapher_load,
};
use paragrapher::bench::Harness;
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::runtime::NativeScan;
use paragrapher::storage::{DeviceKind, SimStore};

const THREADS: usize = 8;
const DISPATCH_LATENCY: f64 = 100e-6;
/// Memory budget scaled the way the datasets are scaled; G5 exceeds it.
const MEMORY_BUDGET: u64 = 4 << 20;

fn main() {
    let mut h = Harness::new("fig5_graph_loading");
    let mut hdd_speedups: Vec<f64> = Vec::new();
    let mut nas_speedups: Vec<f64> = Vec::new();

    for dataset in Dataset::ALL {
        let g = dataset.generate(1, 42);
        for device in [DeviceKind::Hdd, DeviceKind::Ssd, DeviceKind::Nas] {
            let store = SimStore::new_scaled(device);
            let mut baseline_meps: Option<f64> = None;
            for format in [FormatKind::TxtCoo, FormatKind::BinCsx, FormatKind::WebGraph] {
                let base = format!("{}-{:?}", dataset.abbr(), format);
                format.write_to_store(&g, &store, &base);
                let case = format!("{}/{}/{}", dataset.abbr(), device.name(), format.name());
                if format != FormatKind::WebGraph
                    && full_load_memory_bytes(g.num_vertices(), g.num_edges())
                        > MEMORY_BUDGET
                {
                    h.report(&case, "me_per_s", -1.0); // the paper's OOM bar
                    continue;
                }
                let meps = match format {
                    FormatKind::WebGraph => {
                        // Blocks >> workers for balance (paper: 40-2000
                        // blocks per graph at 64M-edge buffers).
                        let buffer = (g.num_edges() / (4 * THREADS as u64)).max(8 << 10);
                        let r = modeled_paragrapher_load(
                            &store,
                            &base,
                            THREADS,
                            buffer,
                            &NativeScan,
                            DISPATCH_LATENCY,
                            None,
                        )
                        .expect("paragrapher load");
                        assert_eq!(r.measurement.edges, g.num_edges());
                        r.measurement.me_per_sec()
                    }
                    _ => {
                        let m = modeled_full_load(&store, &base, format, THREADS)
                            .expect("baseline load");
                        m.me_per_sec()
                    }
                };
                h.report(&case, "me_per_s", meps);
                if format == FormatKind::BinCsx {
                    baseline_meps = Some(meps);
                }
                if format == FormatKind::WebGraph {
                    if let Some(base_meps) = baseline_meps {
                        let speedup = meps / base_meps;
                        h.report(
                            &format!("{}/{}/speedup-vs-bincsx", dataset.abbr(), device.name()),
                            "x",
                            speedup,
                        );
                        match device {
                            DeviceKind::Hdd => hdd_speedups.push(speedup),
                            DeviceKind::Nas => nas_speedups.push(speedup),
                            _ => {}
                        }
                    }
                }
            }
        }
    }

    let max_hdd = hdd_speedups.iter().cloned().fold(0.0, f64::max);
    let max_nas = nas_speedups.iter().cloned().fold(0.0, f64::max);
    h.note(&format!(
        "max HDD load speedup vs Bin CSX: {max_hdd:.2}x (paper: up to 3.2x); NAS: {max_nas:.2}x (paper: 7.3x)"
    ));
    assert!(
        max_hdd > 1.5,
        "compressed loading must beat binary CSX on HDD (got {max_hdd:.2}x)"
    );
    assert!(
        max_nas >= max_hdd,
        "NAS (slower link) should benefit at least as much as HDD"
    );
    h.finish();
}
