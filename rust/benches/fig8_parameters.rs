//! Fig. 8 — ParaGrapher load time for worker counts {9, 18, 36} × buffer
//! sizes {8, 64, 128} M-edges (scaled to the suite: {8Ki, 64Ki, 128Ki}),
//! on HDD and SSD.
//!
//! Paper shapes: on HDD more threads *degrade* (seek interleaving); on SSD
//! few threads underuse the device; very large buffers cause imbalance
//! (few blocks vs workers); very small buffers pay the scheduler's polling
//! latency per block (§5.5).

use paragrapher::bench::workloads::modeled_paragrapher_load;
use paragrapher::bench::Harness;
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::runtime::NativeScan;
use paragrapher::storage::{DeviceKind, SimStore};

const DISPATCH_LATENCY: f64 = 50e-6; // scheduler poll roundtrip (scaled, §5.5)

fn main() {
    let mut h = Harness::new("fig8_parameters");
    let dataset = Dataset::Tw;
    // Large enough that blocks outnumber workers at every buffer size
    // (the paper: 2.4B-edge TW over 8-128M-edge buffers).
    let g = dataset.generate(16, 42);
    let mut grid: Vec<(DeviceKind, usize, u64, f64)> = Vec::new();

    for device in [DeviceKind::Hdd, DeviceKind::Ssd] {
        let store = SimStore::new_scaled(device);
        let base = dataset.abbr().to_string();
        FormatKind::WebGraph.write_to_store(&g, &store, &base);
        for &workers in &[9usize, 18, 36] {
            for &buffer_edges in &[8u64 << 10, 64 << 10, 128 << 10] {
                let r = modeled_paragrapher_load(
                    &store,
                    &base,
                    workers,
                    buffer_edges,
                    &NativeScan,
                    DISPATCH_LATENCY,
                    None,
                )
                .expect("load");
                assert_eq!(r.measurement.edges, g.num_edges());
                let secs = r.measurement.elapsed;
                h.report(
                    &format!(
                        "{}/{}w/{}Ki-edges",
                        device.name(),
                        workers,
                        buffer_edges >> 10
                    ),
                    "seconds",
                    secs,
                );
                grid.push((device, workers, buffer_edges, secs));
            }
        }
    }

    let get = |d: DeviceKind, w: usize, b: u64| {
        grid.iter()
            .find(|(gd, gw, gb, _)| *gd == d && *gw == w && *gb == b)
            .map(|(_, _, _, s)| *s)
            .unwrap()
    };
    // HDD: 36 workers must not beat 9 workers (seek interleaving).
    let hdd9 = get(DeviceKind::Hdd, 9, 64 << 10);
    let hdd36 = get(DeviceKind::Hdd, 36, 64 << 10);
    assert!(
        hdd36 >= hdd9 * 0.95,
        "HDD should degrade (or at best hold) with more workers: 9w {hdd9:.3}s vs 36w {hdd36:.3}s"
    );
    // SSD: 36 workers must beat 9 workers.
    let ssd9 = get(DeviceKind::Ssd, 9, 64 << 10);
    let ssd36 = get(DeviceKind::Ssd, 36, 64 << 10);
    assert!(
        ssd36 < ssd9,
        "SSD should improve with workers: 9w {ssd9:.3}s vs 36w {ssd36:.3}s"
    );
    // Small buffers pay dispatch latency (visible on the fast device).
    let ssd_small = get(DeviceKind::Ssd, 18, 8 << 10);
    let ssd_mid = get(DeviceKind::Ssd, 18, 64 << 10);
    assert!(
        ssd_small > ssd_mid,
        "8Ki buffers must pay scheduler overhead: {ssd_small:.3}s vs {ssd_mid:.3}s"
    );
    h.note(&format!(
        "HDD 9w {hdd9:.3}s -> 36w {hdd36:.3}s | SSD 9w {ssd9:.3}s -> 36w {ssd36:.3}s | SSD small-buffer penalty {:.1}%",
        (ssd_small / ssd_mid - 1.0) * 100.0
    ));
    h.finish();
}
