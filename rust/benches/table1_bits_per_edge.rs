//! Table 1 — bits/edge of each storage format.
//!
//! Paper reference values: Matrix Market (Txt. COO) 82.9, Adjacency Graph
//! (Txt. CSX) 84.5, Binary CSX 32.8, WebGraph 13.2. Exact values depend on
//! the graph mix; the *ordering* and rough magnitudes must reproduce.

use paragrapher::bench::Harness;
use paragrapher::formats::webgraph::{compress, WgParams};
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::storage::{DeviceKind, SimStore};
use paragrapher::util::json::Json;

fn main() {
    let mut h = Harness::new("table1_bits_per_edge");
    let store = SimStore::new(DeviceKind::Dram);
    let mut per_format: std::collections::HashMap<FormatKind, Vec<f64>> =
        std::collections::HashMap::new();

    for d in Dataset::ALL {
        let g = d.generate(1, 42);
        for fk in FormatKind::ALL {
            let base = format!("{}-{:?}", d.abbr(), fk);
            fk.write_to_store(&g, &store, &base);
            let bpe = fk.bits_per_edge(&g, &store, &base);
            h.report(&format!("{}/{}", d.abbr(), fk.name()), "bits_per_edge", bpe);
            per_format.entry(fk).or_default().push(bpe);
        }
        // Per-technique breakdown of the WebGraph encoder (DESIGN §4).
        let (_, _, stats) = compress(&g, WgParams::default());
        let m = g.num_edges() as f64;
        h.report(
            &format!("{}/wg-copied-fraction", d.abbr()),
            "fraction",
            stats.copied_edges as f64 / m,
        );
        h.report(
            &format!("{}/wg-interval-fraction", d.abbr()),
            "fraction",
            stats.interval_edges as f64 / m,
        );
    }

    // Format means + the Table 1 ordering assertions.
    let mean = |fk: FormatKind| -> f64 {
        let v = &per_format[&fk];
        v.iter().sum::<f64>() / v.len() as f64
    };
    let (coo, csx, bin, wg) = (
        mean(FormatKind::TxtCoo),
        mean(FormatKind::TxtCsx),
        mean(FormatKind::BinCsx),
        mean(FormatKind::WebGraph),
    );
    let mut summary = Json::obj();
    summary
        .set("txt_coo_mean", coo)
        .set("txt_csx_mean", csx)
        .set("bin_csx_mean", bin)
        .set("webgraph_mean", wg)
        .set("paper_reference", {
            let mut p = Json::obj();
            p.set("txt_coo", 82.9).set("txt_csx", 84.5).set("bin_csx", 32.8).set("webgraph", 13.2);
            p
        });
    h.attach("summary", summary);
    h.note(&format!(
        "means: COO {coo:.1} | CSX {csx:.1} | Bin {bin:.1} | WG {wg:.1}  (paper: 82.9 / 84.5 / 32.8 / 13.2)"
    ));
    assert!(wg < bin && bin < coo.min(csx), "Table 1 ordering must hold");
    assert!(wg < 20.0, "WebGraph must land in the tens of bits/edge: {wg:.1}");

    // §7 ablation: locality-destroying relabeling vs BFS re-ordering.
    {
        use paragrapher::graph::relabel::{apply_permutation, bfs_order};
        use paragrapher::util::rng::Xoshiro256;
        let g = Dataset::Cw.generate(1, 42);
        let bits = |g: &paragrapher::graph::CsrGraph| {
            compress(g, WgParams::default()).2.total_bits as f64 / g.num_edges() as f64
        };
        let natural = bits(&g);
        let mut rng = Xoshiro256::seed_from_u64(17);
        let mut shuffle: Vec<u32> = (0..g.num_vertices() as u32).collect();
        rng.shuffle(&mut shuffle);
        let shuffled = apply_permutation(&g, &shuffle);
        let random = bits(&shuffled);
        let recovered = bits(&apply_permutation(&shuffled, &bfs_order(&shuffled)));
        h.report("ablation/CW-natural-order", "bits_per_edge", natural);
        h.report("ablation/CW-random-order", "bits_per_edge", random);
        h.report("ablation/CW-bfs-reorder", "bits_per_edge", recovered);
        h.note("locality ablation: random relabeling destroys compression; BFS reordering recovers much of it (the paper's §7 locality-optimizing literature)");
        assert!(random > natural * 1.5 && recovered < random);
    }
    h.finish();
}
