//! Fig. 9 — decompression scalability: datasets resident in DRAM (no
//! storage delays), worker counts 16 → 128.
//!
//! Paper shape: only ~3.8× speedup from 16 to 128 cores, limited by the
//! *sequential* metadata-load phase (12.9–60.6 % of execution). The same
//! Amdahl composition drives our model: elapsed = sequential + parallel
//! CPU spread over `cores`.

use paragrapher::bench::workloads::modeled_paragrapher_load;
use paragrapher::bench::Harness;
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::runtime::NativeScan;
use paragrapher::storage::{DeviceKind, SimStore};

fn main() {
    let mut h = Harness::new("fig9_scalability");
    for dataset in [Dataset::Tw, Dataset::Cw, Dataset::Ms] {
        // Scale 4: decode runs long enough that real-CPU measurement noise
        // cannot distort the Amdahl curve.
        let g = dataset.generate(4, 42);
        let store = SimStore::new_scaled(DeviceKind::Dram);
        let base = dataset.abbr().to_string();
        FormatKind::WebGraph.write_to_store(&g, &store, &base);

        let mut t16 = 0.0f64;
        let mut t128 = 0.0f64;
        for &cores in &[16usize, 32, 64, 128] {
            let buffer = (g.num_edges() / (4 * cores as u64)).max(512);
            // Best of three runs: decode CPU is measured wall time on a
            // shared host; min is the stable estimator.
            let mut secs = f64::INFINITY;
            let mut seq = f64::INFINITY;
            for _ in 0..3 {
                let r = modeled_paragrapher_load(
                    &store,
                    &base,
                    cores,
                    buffer,
                    &NativeScan,
                    20e-6,
                    Some(cores),
                )
                .expect("load");
                assert_eq!(r.measurement.edges, g.num_edges());
                if r.measurement.elapsed < secs {
                    secs = r.measurement.elapsed;
                    seq = r.sequential_seconds;
                }
            }
            h.report(&format!("{}/{}cores", dataset.abbr(), cores), "seconds", secs);
            let seq_frac = seq / secs;
            h.report(
                &format!("{}/{}cores-seq-fraction", dataset.abbr(), cores),
                "fraction",
                seq_frac,
            );
            if cores == 16 {
                t16 = secs;
            }
            if cores == 128 {
                t128 = secs;
            }
        }
        let speedup = t16 / t128;
        h.report(&format!("{}/speedup-16-to-128", dataset.abbr()), "x", speedup);
        assert!(
            speedup >= 1.0 && speedup <= 8.0,
            "{}: Amdahl-limited speedup expected (paper: <= 3.8x), got {speedup:.2}x",
            dataset.abbr()
        );
    }
    h.note("paper: up to 3.8x from 16->128 cores; sequential fraction 12.9-60.6%");
    h.finish();
}
