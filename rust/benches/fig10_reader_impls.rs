//! Fig. 10 — reader-implementation overhead.
//!
//! The paper compares the Java reader against the C one (Java reaches
//! 78–101 % of C). Our analogues:
//! * `BufferedCopy` (managed-style staging copies) vs `ZeroCopy` readers
//!   through the same device model — the "language/runtime tax" on read
//!   bandwidth;
//! * native-Rust vs XLA-offloaded gap-scan decode — the engine ablation
//!   on the decompression path.

use std::time::Instant;

use paragrapher::bench::workloads::modeled_paragrapher_load;
use paragrapher::bench::Harness;
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::runtime::{ArtifactSet, NativeScan, XlaScanEngine};
use paragrapher::storage::reader::ReaderImpl;
use paragrapher::storage::sim::ReadCtx;
use paragrapher::storage::{DeviceKind, IoAccount, ReadMethod, SimStore};

const FILE_BYTES: usize = 24 << 20;

fn main() {
    let mut h = Harness::new("fig10_reader_impls");

    // (a) Reader style vs device bandwidth: elapsed = virtual I/O + real
    // copy CPU; the managed reader's staging pass eats into bandwidth
    // exactly like the paper's Java reader does.
    for device in [DeviceKind::Hdd, DeviceKind::Ssd] {
        let store = SimStore::new(device);
        store.put("f", vec![0x5Au8; FILE_BYTES]);
        let mut ratio_inputs = Vec::new();
        for reader in [ReaderImpl::ZeroCopy, ReaderImpl::BufferedCopy] {
            store.drop_cache();
            let ctx = ReadCtx {
                threads: 1,
                block: 4 << 20,
                method: ReadMethod::Pread,
                sequential: true,
                reader_impl: reader,
            };
            let acct = IoAccount::new();
            let f = store.open("f").unwrap();
            let mut pos = 0u64;
            while pos < FILE_BYTES as u64 {
                let out = f.read(pos, 4 << 20, ctx, &acct);
                std::hint::black_box(&out);
                pos += 4 << 20;
            }
            let bw = FILE_BYTES as f64 / acct.elapsed_seconds();
            h.report(
                &format!("{}/{}", device.name(), reader.name()),
                "MB_per_s",
                bw / 1e6,
            );
            ratio_inputs.push(bw);
        }
        let pct = ratio_inputs[1] / ratio_inputs[0] * 100.0;
        h.report(&format!("{}/managed-vs-zero-copy", device.name()), "percent", pct);
        assert!(
            pct <= 101.0,
            "managed reader cannot beat zero-copy: {pct:.0}%"
        );
        // The paper's window is 78-101%; ours depends on host CPU, accept a
        // wider envelope but require the tax to exist on the fast device.
        if device == DeviceKind::Ssd {
            assert!(pct < 100.0, "the copy tax must be visible on SSD: {pct:.0}%");
        }
    }

    // (b) Decode-engine ablation: native scan vs XLA/Pallas scan.
    let g = Dataset::Tw.generate(1, 42);
    let store = SimStore::new(DeviceKind::Dram);
    FormatKind::WebGraph.write_to_store(&g, &store, "tw");
    let t0 = Instant::now();
    let native = modeled_paragrapher_load(&store, "tw", 4, 128 << 10, &NativeScan, 0.0, None)
        .expect("native load");
    let native_wall = t0.elapsed().as_secs_f64();
    h.report("decode/native-scan", "modeled_s", native.measurement.elapsed);
    h.report("decode/native-scan", "wall_s", native_wall);
    match ArtifactSet::load(ArtifactSet::default_dir()) {
        Ok(arts) => {
            let engine = XlaScanEngine::new(arts);
            let t1 = Instant::now();
            let xla =
                modeled_paragrapher_load(&store, "tw", 4, 128 << 10, &engine, 0.0, None)
                    .expect("xla load");
            let xla_wall = t1.elapsed().as_secs_f64();
            assert_eq!(xla.measurement.edges, native.measurement.edges);
            h.report("decode/xla-pallas-scan", "modeled_s", xla.measurement.elapsed);
            h.report("decode/xla-pallas-scan", "wall_s", xla_wall);
            h.report(
                "decode/xla-vs-native",
                "percent",
                native_wall / xla_wall * 100.0,
            );
            h.note("XLA path on CPU-PJRT pays per-call + copy overhead; on a real TPU the same HLO amortizes across the 64Ki-block (DESIGN §8)");
        }
        Err(e) => h.note(&format!("XLA ablation skipped: {e}")),
    }
    h.finish();
}
