//! Fig. 4 — HDD and SSD read bandwidth over (block size × threads × read
//! method), through the calibrated device models AND verified end-to-end
//! through the SimStore read path on a scaled file.
//!
//! Paper observations to reproduce: (i) HDD saturates with one thread and
//! *degrades* with more; (ii) SSD needs many threads to reach 3.6 GB/s and
//! a single thread reads ~2–2.1 GB/s; (iii) mmap reduces SSD bandwidth and
//! O_DIRECT does not rescue it.

use paragrapher::bench::Harness;
use paragrapher::storage::sim::ReadCtx;
use paragrapher::storage::{DeviceKind, IoAccount, ReadMethod, SimStore};
use paragrapher::storage::reader::ReaderImpl;
use paragrapher::util::chunk_range;

/// Scaled stand-in for the paper's 12 GB benchmark file.
const FILE_BYTES: usize = 48 << 20;

fn main() {
    let mut h = Harness::new("fig4_storage_bandwidth");

    for device in [DeviceKind::Hdd, DeviceKind::Ssd] {
        let m = device.model();
        for &block in &[4u64 << 10, 4 << 20] {
            for &threads in &[1usize, 18, 36] {
                for method in ReadMethod::ALL {
                    let bw = m.aggregate_bandwidth(threads, block, method, true);
                    h.report(
                        &format!(
                            "{}/{}KB/{}t/{}",
                            device.name(),
                            block >> 10,
                            threads,
                            method.name()
                        ),
                        "MB_per_s",
                        bw / 1e6,
                    );
                }
            }
        }
    }

    // End-to-end verification through SimFile reads: partition the file
    // between threads on block granularity (the paper's methodology) and
    // derive bandwidth from the virtual clock.
    h.note("verification through the SimStore read path (12GB scaled to 48MB):");
    for device in [DeviceKind::Hdd, DeviceKind::Ssd] {
        let store = SimStore::new(device);
        store.put("f", vec![0xA5u8; FILE_BYTES]);
        for &(threads, block) in &[(1usize, 4u64 << 20), (18, 4 << 20), (18, 4 << 10)] {
            store.drop_cache();
            let ctx = ReadCtx {
                threads,
                block,
                method: ReadMethod::Pread,
                sequential: true,
                reader_impl: ReaderImpl::ZeroCopy,
            };
            let accounts: Vec<IoAccount> = (0..threads).map(|_| IoAccount::new()).collect();
            let f = store.open("f").unwrap();
            for (t, acct) in accounts.iter().enumerate() {
                let (s, e) = chunk_range(FILE_BYTES, threads, t);
                let mut pos = s as u64;
                while pos < e as u64 {
                    let len = block.min(e as u64 - pos);
                    let _ = f.read_zero_copy(pos, len, ctx, acct);
                    pos += len;
                }
            }
            let elapsed = paragrapher::storage::vclock::phase_elapsed(&accounts);
            let bw = FILE_BYTES as f64 / elapsed;
            h.report(
                &format!("verify/{}/{}t/{}KB", device.name(), threads, block >> 10),
                "MB_per_s",
                bw / 1e6,
            );
        }
    }

    // The paper's qualitative assertions.
    let hdd = DeviceKind::Hdd.model();
    let ssd = DeviceKind::Ssd.model();
    let hdd1 = hdd.aggregate_bandwidth(1, 4 << 20, ReadMethod::Pread, true);
    let hdd36 = hdd.aggregate_bandwidth(36, 4 << 20, ReadMethod::Pread, true);
    let ssd1 = ssd.aggregate_bandwidth(1, 4 << 20, ReadMethod::Pread, true);
    let ssd18 = ssd.aggregate_bandwidth(18, 4 << 20, ReadMethod::Pread, true);
    let ssd_mmap = ssd.aggregate_bandwidth(18, 4 << 20, ReadMethod::Mmap, true);
    assert!(hdd36 < hdd1, "HDD degrades with threads");
    assert!(ssd18 > 1.5 * ssd1, "SSD needs threads to saturate");
    assert!(ssd_mmap < 0.75 * ssd18, "mmap costs SSD bandwidth");
    h.note(&format!(
        "HDD 1t {:.0} MB/s -> 36t {:.0} MB/s | SSD 1t {:.2} GB/s -> 18t {:.2} GB/s (mmap {:.2} GB/s)",
        hdd1 / 1e6,
        hdd36 / 1e6,
        ssd1 / 1e9,
        ssd18 / 1e9,
        ssd_mmap / 1e9
    ));
    h.finish();
}
