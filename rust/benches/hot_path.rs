//! Hot-path microbenchmarks (wall-clock, used by the §Perf optimization
//! pass): bit-stream decode rate, instantaneous-code decode rates, the
//! WebGraph encoder/decoder edge rates, gap-scan engines, and JT-CC union
//! throughput. These are the real-CPU numbers that feed the calibrated
//! decompression bandwidth d.

use paragrapher::bench::Harness;
use paragrapher::formats::webgraph::{self, DecodeSink, WgParams};
use paragrapher::formats::{FormatKind, GraphSource, SourceConfig, WebGraphSource};
use paragrapher::graph::generators;
use paragrapher::metrics::cache_report;
use paragrapher::runtime::{ArtifactSet, NativeScan, ScanEngine, XlaScanEngine};
use paragrapher::storage::sim::ReadCtx;
use paragrapher::storage::{DeviceKind, IoAccount, SimStore};
use paragrapher::util::bitstream::{BitReader, BitWriter};
use paragrapher::util::codes::{Code, CodeReader};
use paragrapher::util::rng::Xoshiro256;

fn main() {
    let mut h = Harness::new("hot_path");
    h.target_seconds = 1.0;

    // Bitstream + codes: the slow-path reference decoder vs the
    // table-driven CodeReader on the same stream. The gap is the direct
    // symbol-rate payoff of the 11-bit peek tables; the value distribution
    // (power-law-ish small gaps) mirrors real residual streams.
    let mut rng = Xoshiro256::seed_from_u64(1);
    let values: Vec<u64> = (0..200_000).map(|_| rng.next_below(100_000)).collect();
    for code in [Code::Gamma, Code::Delta, Code::Zeta(3)] {
        let mut w = BitWriter::new();
        for &v in &values {
            code.write(&mut w, v);
        }
        let bytes = w.into_bytes();
        let name = format!("decode/{code:?}");
        let s = h.bench(&name, || {
            let mut r = BitReader::new(&bytes);
            let mut acc = 0u64;
            for _ in 0..values.len() {
                acc = acc.wrapping_add(code.read(&mut r).unwrap());
            }
            acc
        });
        h.report(&name, "Mvalues_per_s", values.len() as f64 / s.min / 1e6);

        let slow_min = s.min;
        let name = format!("decode-table/{code:?}");
        let s = h.bench(&name, || {
            let mut r = BitReader::new(&bytes);
            let mut reader = CodeReader::new(code);
            let mut acc = 0u64;
            for _ in 0..values.len() {
                acc = acc.wrapping_add(reader.read(&mut r).unwrap());
            }
            acc
        });
        h.report(&name, "Mvalues_per_s", values.len() as f64 / s.min / 1e6);
        h.report(&name, "speedup_vs_slow_path", slow_min / s.min);
        let mut probe = CodeReader::new(code);
        let mut r = BitReader::new(&bytes);
        for _ in 0..values.len() {
            let _ = probe.read(&mut r).unwrap();
        }
        h.report(&name, "table_hit_rate", probe.hit_rate());
    }

    // Encoder/decoder edge rates on a web-like graph.
    let g = generators::barabasi_albert(20_000, 12, 3);
    let edges = g.num_edges();
    let s = h.bench("webgraph/compress", || {
        webgraph::compress(&g, WgParams::default()).2.total_bits
    });
    h.report("webgraph/compress", "ME_per_s", edges as f64 / s.min / 1e6);

    let store = SimStore::new(DeviceKind::Dram);
    FormatKind::WebGraph.write_to_store(&g, &store, "g");
    let acct = IoAccount::new();
    let meta = webgraph::read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
    let offs = webgraph::read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
    let dec =
        webgraph::Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).unwrap();
    let s = h.bench("webgraph/decode-full", || {
        dec.decode_range(0, meta.num_vertices, &acct).unwrap().num_edges()
    });
    h.report("webgraph/decode-full", "ME_per_s", edges as f64 / s.min / 1e6);
    // The calibrated single-core decompression bandwidth d (bytes of
    // uncompressed CSR per second) — the §3 model's d.
    h.report("webgraph/calibrated-d", "MB_per_s", edges as f64 * 4.0 / s.min / 1e6);

    // Same decode through one explicitly reused DecodeScratch: the
    // steady-state (allocation-free) shape the coordinator's pool workers
    // run block after block. Reported next to decode-full so scratch reuse
    // and the decode tables stay visible as separate effects.
    let mut scratch = webgraph::DecodeScratch::new();
    let s = h.bench("webgraph/decode-full-warm-scratch", || {
        dec.decode_range_scratch(0, meta.num_vertices, &acct, &NativeScan, &mut scratch)
            .unwrap()
            .num_edges()
    });
    h.report("webgraph/decode-full-warm-scratch", "ME_per_s", edges as f64 / s.min / 1e6);
    h.report("webgraph/decode-full-warm-scratch", "table_hit_rate", scratch.table_hit_rate());

    let s = h.bench("webgraph/decode-single-vertex", || {
        dec.decode_vertex(10_000, &acct).unwrap().len()
    });
    h.report("webgraph/decode-single-vertex", "us", s.min * 1e6);

    // Observability overhead guard: the coordinator's per-block decode
    // shape — chunked decode with one histogram record and one span per
    // block — with recording enabled vs killed (PG_OBS semantics via
    // set_enabled). The instrumentation is a timestamp pair, one bucketed
    // fetch_add, and one ring push per ~2k-vertex block, so losing more
    // than 3% means the hot path grew an allocation or a contended lock.
    {
        use paragrapher::obs;
        let hist = obs::Histo::detached();
        let mut off_buf: Vec<u64> = Vec::new();
        let mut edge_buf: Vec<u32> = Vec::new();
        let n = meta.num_vertices;
        let chunk = 2_048usize;
        let was = obs::enabled();
        let mut pass = |h: &mut Harness, name: &str, on: bool| {
            obs::set_enabled(on);
            h.bench(name, || {
                let mut delivered = 0u64;
                let mut vs = 0usize;
                while vs < n {
                    let ve = (vs + chunk).min(n);
                    let t0 = std::time::Instant::now();
                    let mut sink = DecodeSink::new(&mut off_buf, &mut edge_buf);
                    dec.decode_range_sink(vs, ve, &acct, &NativeScan, &mut sink).unwrap();
                    let dur = t0.elapsed();
                    hist.record_duration(dur);
                    obs::tracer().record("bench", "decode-block", t0, dur, 0, vs as u64);
                    delivered += *off_buf.last().unwrap_or(&0);
                    vs = ve;
                }
                delivered
            })
        };
        let s_on = pass(&mut h, "obs/decode-tracing-on", true);
        let s_off = pass(&mut h, "obs/decode-tracing-off", false);
        obs::set_enabled(was);
        h.report("obs/decode-tracing-on", "ME_per_s", edges as f64 / s_on.min / 1e6);
        h.report("obs/decode-tracing-off", "ME_per_s", edges as f64 / s_off.min / 1e6);
        h.report("obs/decode-tracing-on", "overhead_vs_off", s_on.min / s_off.min);
        assert!(
            s_on.min <= s_off.min * 1.03,
            "span+histogram recording must cost < 3% of block decode: {}s on vs {}s off",
            s_on.min,
            s_off.min
        );
    }

    // Zero-copy delivery (tentpole): decode straight into library-owned
    // buffer storage via DecodeSink vs the former decode-then-copy
    // pipeline, on the modeled SSD tier the acceptance criterion names.
    // The sink path does strictly less work (no intermediate block, no
    // memcpy), so losing here is a regression, not noise.
    {
        let store_zc = SimStore::new(DeviceKind::Ssd);
        FormatKind::WebGraph.write_to_store(&g, &store_zc, "zc");
        let acct_zc = IoAccount::new();
        let meta_zc =
            webgraph::read_meta(&store_zc, "zc", ReadCtx::default(), &acct_zc).unwrap();
        let offs_zc =
            webgraph::read_offsets(&store_zc, "zc", ReadCtx::default(), &acct_zc).unwrap();
        let dec_zc = webgraph::Decoder::open(
            &store_zc, "zc", &meta_zc, &offs_zc, ReadCtx::default(), &acct_zc,
        )
        .unwrap();
        let nzc = meta_zc.num_vertices;
        let mut buf_offsets: Vec<u64> = Vec::new();
        let mut buf_edges: Vec<u32> = Vec::new();
        let s_copy = h.bench("delivery/decode-then-copy", || {
            let blockz = dec_zc.decode_range(0, nzc, &acct_zc).unwrap();
            buf_offsets.clear();
            buf_edges.clear();
            buf_offsets.extend_from_slice(&blockz.offsets);
            buf_edges.extend_from_slice(&blockz.edges);
            buf_edges.len()
        });
        h.report("delivery/decode-then-copy", "ME_per_s", edges as f64 / s_copy.min / 1e6);
        let s_sink = h.bench("delivery/decode-into-sink", || {
            let mut sink = DecodeSink::new(&mut buf_offsets, &mut buf_edges);
            dec_zc.decode_range_sink(0, nzc, &acct_zc, &NativeScan, &mut sink).unwrap();
            buf_edges.len()
        });
        h.report("delivery/decode-into-sink", "ME_per_s", edges as f64 / s_sink.min / 1e6);
        h.report("delivery/decode-into-sink", "speedup_vs_copy", s_copy.min / s_sink.min);
        // Regression gate with shared-runner headroom: the sink path does
        // strictly less work, so losing by >10% even on min-of-N is a real
        // reintroduced copy/allocation, not noise (the precise speedup is
        // reported above for trend tracking).
        assert!(
            s_sink.min <= s_copy.min * 1.10,
            "decode-into-sink must not lose to decode-then-copy: {}s vs {}s",
            s_sink.min,
            s_copy.min
        );
    }

    // Real-file load paths on the same on-disk fixture: mmap zero-copy vs
    // pread vs the buffered-copy reader, all warm (second pass onward, so
    // every page sits in the modeled cache and — for mmap — in the real
    // page cache). Warm mmap serves borrowed slices with no syscall per
    // block, so losing to pread by >10% means the mapping path grew a copy
    // or a fault storm, not noise.
    {
        use paragrapher::storage::reader::ReaderImpl;
        use paragrapher::storage::{GraphStore, ReadMethod};
        let dir = std::env::temp_dir().join(format!("pg_hot_path_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        for (name, data) in webgraph::serialize(&g, "disk") {
            std::fs::write(dir.join(&name), data).unwrap();
        }
        let store_d = GraphStore::open_dir(&dir, DeviceKind::Ssd).unwrap();
        let mut buf_offsets: Vec<u64> = Vec::new();
        let mut buf_edges: Vec<u32> = Vec::new();
        let mut mins = [0.0f64; 3];
        let passes = [
            ("load/mmap", ReadMethod::Mmap, ReaderImpl::ZeroCopy),
            ("load/pread", ReadMethod::Pread, ReaderImpl::ZeroCopy),
            ("load/buffered-copy", ReadMethod::Pread, ReaderImpl::BufferedCopy),
        ];
        for (i, &(name, method, reader_impl)) in passes.iter().enumerate() {
            let ctx = ReadCtx { method, reader_impl, ..ReadCtx::default() };
            let acct_d = IoAccount::new();
            let meta_d = webgraph::read_meta(&store_d, "disk", ctx, &acct_d).unwrap();
            let offs_d = webgraph::read_offsets(&store_d, "disk", ctx, &acct_d).unwrap();
            let dec_d =
                webgraph::Decoder::open(&store_d, "disk", &meta_d, &offs_d, ctx, &acct_d)
                    .unwrap();
            let nd = meta_d.num_vertices;
            // Warm pass: fault every page in before timing.
            let mut sink = DecodeSink::new(&mut buf_offsets, &mut buf_edges);
            dec_d.decode_range_sink(0, nd, &acct_d, &NativeScan, &mut sink).unwrap();
            let s = h.bench(name, || {
                let mut sink = DecodeSink::new(&mut buf_offsets, &mut buf_edges);
                dec_d.decode_range_sink(0, nd, &acct_d, &NativeScan, &mut sink).unwrap();
                buf_edges.len()
            });
            h.report(name, "ME_per_s", edges as f64 / s.min / 1e6);
            mins[i] = s.min;
        }
        h.report("load/mmap", "speedup_vs_pread", mins[1] / mins[0]);
        h.report("load/mmap", "speedup_vs_buffered_copy", mins[2] / mins[0]);
        assert!(
            mins[0] <= mins[1] * 1.10,
            "warm mmap load must not lose to pread: {}s vs {}s",
            mins[0],
            mins[1]
        );
        drop(store_d);
        std::fs::remove_dir_all(&dir).ok();
    }

    // COO trim: borrowed view vs the former per-callback copy. Both run
    // the same offsets rebase; the contrast is the edge memcpy the view
    // skips (the `coo_get_edges` delivery path).
    {
        let block = dec.decode_range(0, meta.num_vertices, &acct).unwrap();
        let m = block.num_edges();
        let (lo, hi) = ((m / 5) as usize, (m - m / 5) as usize);
        let rebase = |block: &webgraph::DecodedBlock, out: &mut Vec<u64>| -> usize {
            out.clear();
            let mut first_v = None;
            for i in 0..block.num_vertices() {
                let (s, e) = (block.offsets[i] as usize, block.offsets[i + 1] as usize);
                if e <= lo || s >= hi {
                    continue;
                }
                if first_v.is_none() {
                    first_v = Some(i);
                    out.push(0);
                }
                out.push((e.min(hi) - lo) as u64);
            }
            first_v.unwrap_or(0)
        };
        let mut offs_scratch: Vec<u64> = Vec::new();
        let s_view = h.bench("coo-trim/view", || {
            let fv = rebase(&block, &mut offs_scratch);
            let trimmed = &block.edges[lo..hi];
            (fv, trimmed[trimmed.len() - 1])
        });
        let mut edge_buf: Vec<u32> = Vec::new();
        let s_copy = h.bench("coo-trim/copy", || {
            let fv = rebase(&block, &mut offs_scratch);
            edge_buf.clear();
            edge_buf.extend_from_slice(&block.edges[lo..hi]);
            (fv, edge_buf[edge_buf.len() - 1])
        });
        h.report("coo-trim/view", "Medges_per_s", (hi - lo) as f64 / s_view.min / 1e6);
        h.report("coo-trim/copy", "Medges_per_s", (hi - lo) as f64 / s_copy.min / 1e6);
        h.report("coo-trim/view", "speedup_vs_copy", s_copy.min / s_view.min);
    }

    // Random-access successors: cold decode (cache disabled) vs DecodedCache
    // hit — the spread is the decompression work the cache saves on hot
    // vertices (the GraphSource out-of-core path).
    let probes: Vec<usize> =
        (0..512).map(|_| rng.next_below(meta.num_vertices as u64) as usize).collect();
    let cold_cfg = SourceConfig { cache_cost: 0, ..SourceConfig::default() };
    let cold_src = WebGraphSource::open(&store, "g", cold_cfg).unwrap();
    let s = h.bench("webgraph/successors-cold", || {
        let mut acc = 0usize;
        for &v in &probes {
            acc += cold_src.successors(v).unwrap().len();
        }
        acc
    });
    h.report("webgraph/successors-cold", "us_per_access", s.min * 1e6 / probes.len() as f64);

    let warm_src = WebGraphSource::open(&store, "g", SourceConfig::default()).unwrap();
    for &v in &probes {
        let _ = warm_src.successors(v).unwrap(); // populate the cache
    }
    let s = h.bench("webgraph/successors-cache-hit", || {
        let mut acc = 0usize;
        for &v in &probes {
            acc += warm_src.successors(v).unwrap().len();
        }
        acc
    });
    h.report(
        "webgraph/successors-cache-hit",
        "us_per_access",
        s.min * 1e6 / probes.len() as f64,
    );
    h.attach("webgraph/successors-cache", cache_report(&warm_src.cache_counters()));

    // Parallel range decode (the tentpole): scaling of decode_range over
    // worker counts on a 100k+-vertex graph. Acceptance bar: 4 workers ≥
    // 2× the 1-worker case.
    let big = generators::rmat(17, 8, 7); // 131072 vertices
    let store_big = SimStore::new(DeviceKind::Dram);
    FormatKind::WebGraph.write_to_store(&big, &store_big, "big");
    let acct_big = IoAccount::new();
    let meta_big = webgraph::read_meta(&store_big, "big", ReadCtx::default(), &acct_big).unwrap();
    let offs_big =
        webgraph::read_offsets(&store_big, "big", ReadCtx::default(), &acct_big).unwrap();
    let dec_big = webgraph::Decoder::open(
        &store_big, "big", &meta_big, &offs_big, ReadCtx::default(), &acct_big,
    )
    .unwrap();
    let nb = meta_big.num_vertices;
    let mut par1_min = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let accounts: Vec<IoAccount> = (0..workers).map(|_| IoAccount::new()).collect();
        let name = format!("webgraph/decode_range-par-{workers}");
        let s = h.bench(&name, || {
            dec_big
                .decode_range_parallel(0, nb, &accounts, &NativeScan)
                .unwrap()
                .num_edges()
        });
        h.report(&name, "ME_per_s", big.num_edges() as f64 / s.min / 1e6);
        if workers == 1 {
            par1_min = s.min;
        } else {
            h.report(&name, "speedup_vs_1w", par1_min / s.min);
        }
    }

    // Elias-Fano offsets vs plain Vec<u64>: random-access latency and
    // resident footprint (acceptance bar: EF ≤ 40% of plain, successors
    // latency within 10% — the successors path above runs on EF already).
    let plain_bits: Vec<u64> = (0..=nb).map(|v| offs_big.bit_offset(v)).collect();
    let ef_probes: Vec<usize> =
        (0..8192).map(|_| rng.next_below(nb as u64 + 1) as usize).collect();
    let s = h.bench("offsets-ef-vs-plain/ef-get", || {
        let mut acc = 0u64;
        for &v in &ef_probes {
            acc = acc.wrapping_add(offs_big.bit_offset(v));
        }
        acc
    });
    h.report("offsets-ef-vs-plain/ef-get", "ns_per_access", s.min * 1e9 / ef_probes.len() as f64);
    let s = h.bench("offsets-ef-vs-plain/plain-get", || {
        let mut acc = 0u64;
        for &v in &ef_probes {
            acc = acc.wrapping_add(plain_bits[v]);
        }
        acc
    });
    h.report(
        "offsets-ef-vs-plain/plain-get",
        "ns_per_access",
        s.min * 1e9 / ef_probes.len() as f64,
    );
    h.report(
        "offsets-ef-vs-plain",
        "footprint_ratio",
        offs_big.size_bytes() as f64 / offs_big.plain_size_bytes() as f64,
    );
    h.attach("offsets-ef-vs-plain", paragrapher::metrics::offsets_report(&offs_big));

    // Interleaved-vs-sequential partitioned loading (the tentpole's §3
    // experiment): per-tier modeled end-to-end time of a 16-partition 1D
    // stream pipelined against a JT-CC-priced consumer, vs the
    // load-then-execute baseline. Asserted bars: strictly below
    // sequential, never below the pipeline floor max(Σload, Σconsume).
    {
        use paragrapher::bench::workloads::modeled_interleaved_run;
        use paragrapher::partition::PartitionPlan;
        let gi = generators::barabasi_albert(30_000, 10, 21);
        for device in [DeviceKind::Hdd, DeviceKind::Ssd] {
            let store_i = SimStore::new(device);
            FormatKind::WebGraph.write_to_store(&gi, &store_i, "i");
            let acct_i = IoAccount::new();
            let offs_i =
                webgraph::read_offsets(&store_i, "i", ReadCtx::default(), &acct_i).unwrap();
            let plan = PartitionPlan::one_d(&offs_i, 16);
            let run = modeled_interleaved_run(&store_i, "i", &plan, 4, 40.0).unwrap();
            let name = format!("interleaved-vs-sequential/{}", device.name());
            assert!(
                run.interleaved < run.sequential,
                "{name}: interleaved {} not below sequential {}",
                run.interleaved,
                run.sequential
            );
            assert!(
                run.interleaved >= run.envelope_floor() - 1e-12,
                "{name}: below the model envelope floor"
            );
            h.report(&name, "speedup_vs_sequential", run.speedup());
            h.report(&name, "overlap_fraction", run.overlap);
            let mut j = paragrapher::util::json::Json::obj();
            j.set("interleaved_s", run.interleaved)
                .set("sequential_s", run.sequential)
                .set("load_s", run.load_seconds)
                .set("consume_s", run.consume_seconds)
                .set("window", run.window as f64)
                .set("balance_factor", plan.balance_factor());
            h.attach(&name, j);
        }
    }

    // Fused scan+validate+narrow vs scan-then-validate: the decoder's
    // phase-2 rewrite — one pass over the block-level gap array instead of
    // an inclusive scan plus a separate validation/narrowing walk.
    {
        let gaps_src: Vec<i64> = (0..1 << 20).map(|_| rng.next_below(48) as i64).collect();
        let upper = 1u64 << 40;
        let mut buf = vec![0i64; gaps_src.len()];
        let mut out: Vec<u32> = Vec::new();
        let s_fused = h.bench("scan/fused-validate-1Mi", || {
            buf.copy_from_slice(&gaps_src);
            let v = NativeScan.scan_validate_u32(&mut buf, upper, &mut out).unwrap();
            assert!(v.is_none());
            out[out.len() - 1]
        });
        h.report(
            "scan/fused-validate-1Mi",
            "Melem_per_s",
            gaps_src.len() as f64 / s_fused.min / 1e6,
        );
        let s_split = h.bench("scan/scan-then-validate-1Mi", || {
            buf.copy_from_slice(&gaps_src);
            paragrapher::bench::workloads::scan_then_validate_reference(
                &mut buf, upper, &mut out,
            );
            out[out.len() - 1]
        });
        h.report(
            "scan/scan-then-validate-1Mi",
            "Melem_per_s",
            gaps_src.len() as f64 / s_split.min / 1e6,
        );
        h.report("scan/fused-validate-1Mi", "speedup_vs_split", s_split.min / s_fused.min);
    }

    // Scan engines.
    let mut gaps: Vec<i64> = (0..1 << 20).map(|_| rng.next_below(64) as i64).collect();
    let s = h.bench("scan/native-1Mi", || {
        let mut copy = gaps.clone();
        NativeScan.inclusive_scan_i64(&mut copy).unwrap();
        copy[copy.len() - 1]
    });
    h.report("scan/native-1Mi", "Melem_per_s", gaps.len() as f64 / s.min / 1e6);
    if let Ok(arts) = ArtifactSet::load(ArtifactSet::default_dir()) {
        let engine = XlaScanEngine::new(arts);
        let s = h.bench("scan/xla-pallas-1Mi", || {
            let mut copy = gaps.clone();
            engine.inclusive_scan_i64(&mut copy).unwrap();
            copy[copy.len() - 1]
        });
        h.report("scan/xla-pallas-1Mi", "Melem_per_s", gaps.len() as f64 / s.min / 1e6);
    }
    gaps.truncate(0);

    // JT-CC union throughput.
    let pairs: Vec<(u32, u32)> = g.iter_edges().collect();
    let s = h.bench("jtcc/union-pass", || {
        let uf = paragrapher::algorithms::jtcc::JtUnionFind::new(g.num_vertices(), 3);
        for &(a, b) in &pairs {
            uf.union(a, b);
        }
        uf.count_components()
    });
    h.report("jtcc/union-pass", "ME_per_s", pairs.len() as f64 / s.min / 1e6);

    h.finish();
}
