//! Fig. 7 — ParaGrapher decompression throughput across storage mediums
//! (HDD, SSD, NVMM, DDR4).
//!
//! Paper shape: throughput grows with the medium up to a ceiling set by
//! the decompression bandwidth d (their peak: 952 ME/s ≈ 3.8 GB/s on
//! DDR4). Our absolute numbers differ (different CPU), but the ordering
//! HDD < SSD ≤ NVMM ≈ DDR4 and the d-ceiling must reproduce.

use paragrapher::bench::workloads::modeled_paragrapher_load;
use paragrapher::bench::Harness;
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::model::calibrate_d;
use paragrapher::runtime::NativeScan;
use paragrapher::storage::{DeviceKind, SimStore};

const THREADS: usize = 8;

fn main() {
    let mut h = Harness::new("fig7_mediums");
    let mut per_device: Vec<(DeviceKind, f64)> = Vec::new();

    for dataset in [Dataset::Tw, Dataset::Cw, Dataset::G5] {
        let g = dataset.generate(1, 42);
        for device in
            [DeviceKind::Hdd, DeviceKind::Ssd, DeviceKind::Nvmm, DeviceKind::Dram]
        {
            let store = SimStore::new_scaled(device);
            let base = dataset.abbr().to_string();
            FormatKind::WebGraph.write_to_store(&g, &store, &base);
            let buffer = (g.num_edges() / (4 * THREADS as u64)).max(8 << 10);
            let r = modeled_paragrapher_load(
                &store, &base, THREADS, buffer, &NativeScan, 100e-6, None,
            )
            .expect("load");
            assert_eq!(r.measurement.edges, g.num_edges());
            let meps = r.measurement.me_per_sec();
            h.report(
                &format!("{}/{}", dataset.abbr(), device.name()),
                "me_per_s",
                meps,
            );
            per_device.push((device, meps));
        }
        // Calibrated d on DRAM (storage-free): edges * 4 B / decode CPU.
        let store = SimStore::new_scaled(DeviceKind::Dram);
        let base = dataset.abbr().to_string();
        FormatKind::WebGraph.write_to_store(&g, &store, &base);
        let buffer = (g.num_edges() / (4 * THREADS as u64)).max(8 << 10);
        let r = modeled_paragrapher_load(
            &store, &base, THREADS, buffer, &NativeScan, 0.0, None,
        )
        .expect("load");
        let d = calibrate_d(g.num_edges() * 4, r.parallel_seconds, 1);
        h.report(&format!("{}/calibrated-d", dataset.abbr()), "MB_per_s", d / 1e6);
    }

    // Ordering check per dataset.
    let mean = |k: DeviceKind| {
        let v: Vec<f64> =
            per_device.iter().filter(|(d, _)| *d == k).map(|(_, m)| *m).collect();
        v.iter().sum::<f64>() / v.len() as f64
    };
    let (hdd, ssd, nvmm, dram) = (
        mean(DeviceKind::Hdd),
        mean(DeviceKind::Ssd),
        mean(DeviceKind::Nvmm),
        mean(DeviceKind::Dram),
    );
    h.note(&format!(
        "mean ME/s: HDD {hdd:.0} < SSD {ssd:.0} <= NVMM {nvmm:.0} <= DDR4 {dram:.0} (decode-bound ceiling)"
    ));
    assert!(hdd < ssd, "HDD must trail SSD");
    assert!(ssd <= nvmm * 1.05, "NVMM at least matches SSD");
    assert!(
        (nvmm - dram).abs() / dram < 0.5,
        "fast mediums converge to the decode ceiling: NVMM {nvmm:.0} vs DDR4 {dram:.0}"
    );
    h.finish();
}
