"""Pallas gap-scan kernel vs. the pure-numpy oracle (exact i64)."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels.gap_scan import BLOCK, TILE, gap_scan  # noqa: E402
from compile.kernels.ref import ref_gap_scan  # noqa: E402

import jax.numpy as jnp  # noqa: E402


def run_kernel(gaps: np.ndarray, carry: int) -> np.ndarray:
    out = gap_scan(jnp.asarray(gaps, dtype=jnp.int64), jnp.int64(carry))
    return np.asarray(out)


def test_zeros():
    gaps = np.zeros(BLOCK, dtype=np.int64)
    np.testing.assert_array_equal(run_kernel(gaps, 0), np.zeros(BLOCK))
    np.testing.assert_array_equal(run_kernel(gaps, 7), np.full(BLOCK, 7))


def test_ones_ramp():
    gaps = np.ones(BLOCK, dtype=np.int64)
    expect = np.arange(1, BLOCK + 1, dtype=np.int64)
    np.testing.assert_array_equal(run_kernel(gaps, 0), expect)


def test_negative_gaps_and_carry():
    rng = np.random.default_rng(3)
    gaps = rng.integers(-1000, 1000, size=BLOCK, dtype=np.int64)
    for carry in (-5, 0, 123456789):
        got = run_kernel(gaps, carry)
        want = ref_gap_scan(gaps, carry)
        np.testing.assert_array_equal(got, want)
        np.testing.assert_allclose(got, want)  # harness uniformity


def test_tile_boundaries_are_seamless():
    # A spike at each tile boundary catches carry-propagation bugs.
    gaps = np.zeros(BLOCK, dtype=np.int64)
    gaps[::TILE] = 1
    got = run_kernel(gaps, 0)
    want = ref_gap_scan(gaps, 0)
    np.testing.assert_array_equal(got, want)
    assert got[-1] == BLOCK // TILE


def test_large_values_no_overflow_in_i64_range():
    gaps = np.full(BLOCK, 2**40, dtype=np.int64)
    got = run_kernel(gaps, 0)
    want = ref_gap_scan(gaps, 0)
    np.testing.assert_array_equal(got, want)


def test_wrong_shape_rejected():
    with pytest.raises(ValueError):
        gap_scan(jnp.zeros(BLOCK - 1, dtype=jnp.int64), jnp.int64(0))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    carry=st.integers(min_value=-(2**40), max_value=2**40),
    lo=st.integers(min_value=-(2**20), max_value=0),
    hi=st.integers(min_value=1, max_value=2**20),
)
def test_hypothesis_random_streams(seed, carry, lo, hi):
    rng = np.random.default_rng(seed)
    gaps = rng.integers(lo, hi, size=BLOCK, dtype=np.int64)
    got = run_kernel(gaps, carry)
    want = ref_gap_scan(gaps, carry)
    np.testing.assert_array_equal(got, want)


def test_realistic_webgraph_segments():
    # Shape of real decoder input: segment heads are (possibly negative)
    # absolute deltas, followed by strictly positive gaps.
    rng = np.random.default_rng(11)
    gaps = rng.integers(1, 64, size=BLOCK, dtype=np.int64)
    seg_starts = rng.choice(BLOCK, size=BLOCK // 100, replace=False)
    gaps[seg_starts] = rng.integers(-10000, 10000, size=len(seg_starts))
    got = run_kernel(gaps, 0)
    np.testing.assert_array_equal(got, ref_gap_scan(gaps, 0))
