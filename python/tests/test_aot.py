"""AOT lowering sanity: every model lowers to HLO text with the declared
fixed shapes, and the emitted text is parseable-looking HLO."""

import jax

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402
from compile.aot import to_hlo_text  # noqa: E402


def test_every_model_lowers():
    shapes = model.example_args()
    for name, fn in model.MODELS.items():
        lowered = jax.jit(fn).lower(*shapes[name])
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text, f"{name}: missing entry computation"


def test_gap_scan_shapes_match_rust_contract():
    shapes = model.example_args()["gap_scan"]
    assert shapes[0].shape == (65_536,)
    assert str(shapes[0].dtype) == "int64"
    assert shapes[1].shape == ()


def test_wcc_shapes_match_rust_contract():
    shapes = model.example_args()["wcc_step"]
    assert all(s.shape == (65_536,) for s in shapes)
    assert all(str(s.dtype) == "int32" for s in shapes)


def test_lowered_hlo_is_deterministic():
    shapes = model.example_args()
    fn = model.MODELS["gap_scan"]
    a = to_hlo_text(jax.jit(fn).lower(*shapes["gap_scan"]))
    b = to_hlo_text(jax.jit(fn).lower(*shapes["gap_scan"]))
    assert a == b
