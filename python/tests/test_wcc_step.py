"""Pallas edge-min kernel + L2 WCC step vs. the numpy oracle."""

import jax
import numpy as np
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from compile.kernels.ref import ref_edge_min, ref_wcc_step  # noqa: E402
from compile.kernels.wcc_step import BLOCK, edge_min  # noqa: E402
from compile.model import wcc_step_model  # noqa: E402


def rand_case(seed: int):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, BLOCK, size=BLOCK, dtype=np.int32)
    src = rng.integers(0, BLOCK, size=BLOCK, dtype=np.int32)
    dst = rng.integers(0, BLOCK, size=BLOCK, dtype=np.int32)
    return labels, src, dst


def test_edge_min_matches_ref():
    labels, src, dst = rand_case(0)
    got = np.asarray(edge_min(jnp.asarray(labels), jnp.asarray(src), jnp.asarray(dst)))
    want = ref_edge_min(labels, src, dst)
    np.testing.assert_array_equal(got, want)


def test_wcc_step_matches_ref():
    labels, src, dst = rand_case(1)
    (got,) = wcc_step_model(jnp.asarray(labels), jnp.asarray(src), jnp.asarray(dst))
    want = ref_wcc_step(labels, src, dst)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_self_loop_padding_is_noop():
    labels = np.arange(BLOCK, dtype=np.int32)
    src = np.zeros(BLOCK, dtype=np.int32)
    dst = np.zeros(BLOCK, dtype=np.int32)
    (got,) = wcc_step_model(jnp.asarray(labels), jnp.asarray(src), jnp.asarray(dst))
    np.testing.assert_array_equal(np.asarray(got), labels)


def test_chain_converges():
    labels = np.arange(BLOCK, dtype=np.int32)
    src = np.zeros(BLOCK, dtype=np.int32)
    dst = np.zeros(BLOCK, dtype=np.int32)
    # Chain 0-1-2-...-9.
    for i in range(9):
        src[i], dst[i] = i, i + 1
    cur = jnp.asarray(labels)
    for _ in range(10):
        (cur,) = wcc_step_model(cur, jnp.asarray(src), jnp.asarray(dst))
    got = np.asarray(cur)
    assert (got[:10] == 0).all()
    assert got[10] == 10


def test_step_is_monotone_nonincreasing():
    labels, src, dst = rand_case(2)
    (got,) = wcc_step_model(jnp.asarray(labels), jnp.asarray(src), jnp.asarray(dst))
    assert (np.asarray(got) <= labels).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_hypothesis_random_blocks(seed):
    labels, src, dst = rand_case(seed)
    (got,) = wcc_step_model(jnp.asarray(labels), jnp.asarray(src), jnp.asarray(dst))
    np.testing.assert_array_equal(np.asarray(got), ref_wcc_step(labels, src, dst))
