"""L2: the jax compute graph the Rust coordinator executes per edge block.

Two entry points, both fixed-shape (AOT contract with rust/src/runtime):

* ``gap_scan_model``  - phase-2 WebGraph decode: residual gaps -> absolute
  neighbor IDs. Wraps the L1 Pallas kernel so it lowers into the same HLO.
* ``wcc_step_model``  - one Weakly-Connected-Components label-propagation
  step over an edge block: the L1 ``edge_min`` Pallas gather kernel plus an
  XLA scatter-min around it (scatter's write collisions belong to XLA, the
  dense gather half belongs to Pallas).
"""

import jax
import jax.numpy as jnp

from .kernels import edge_min, gap_scan
from .kernels.gap_scan import BLOCK as GAP_SCAN_BLOCK
from .kernels.wcc_step import BLOCK as WCC_BLOCK


def gap_scan_model(gaps: jax.Array, carry: jax.Array) -> tuple:
    """i64[GAP_SCAN_BLOCK], i64[] -> (i64[GAP_SCAN_BLOCK],)."""
    return (gap_scan(gaps, carry),)


def wcc_step_model(labels: jax.Array, src: jax.Array, dst: jax.Array) -> tuple:
    """i32[WCC_BLOCK] x3 -> (i32[WCC_BLOCK],).

    labels'[v] = min(labels[v], min over incident edges of edge-min).
    Padding convention: unused edge slots hold (0, 0) self-edges (no-ops).
    """
    m = edge_min(labels, src, dst)
    out = labels.at[src].min(m, mode="drop")
    out = out.at[dst].min(m, mode="drop")
    return (out,)


def example_args():
    """Concrete ShapeDtypeStructs for AOT lowering."""
    i64 = jnp.int64
    i32 = jnp.int32
    return {
        "gap_scan": (
            jax.ShapeDtypeStruct((GAP_SCAN_BLOCK,), i64),
            jax.ShapeDtypeStruct((), i64),
        ),
        "wcc_step": (
            jax.ShapeDtypeStruct((WCC_BLOCK,), i32),
            jax.ShapeDtypeStruct((WCC_BLOCK,), i32),
            jax.ShapeDtypeStruct((WCC_BLOCK,), i32),
        ),
    }


MODELS = {
    "gap_scan": gap_scan_model,
    "wcc_step": wcc_step_model,
}
