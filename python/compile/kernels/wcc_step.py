"""L1 Pallas kernel: per-edge label minimum (the gather half of one WCC
label-propagation step).

One WCC step over an edge block (the paper's partial-processing workload,
S5.3) is: for every edge (u, v), m = min(label[u], label[v]); then
label[u] <- min(label[u], m) and label[v] <- min(label[v], m).

The gather + minimum over the edge block is a dense, perfectly vectorizable
kernel - it lives here in Pallas. The scatter-min (data-dependent write
collisions) composes around it in the L2 jax model, lowering to an XLA
scatter with a min combiner in the same HLO module.

VMEM budget per grid step: labels (full array, 256 KiB for 64 Ki i32) +
one TILE of src/dst/out (3 x 32 KiB) - comfortably resident.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Must match rust/src/runtime/exec.rs::WCC_BLOCK.
BLOCK = 65_536
TILE = 8_192


def _edge_min_kernel(labels_ref, src_ref, dst_ref, o_ref):
    labels = labels_ref[...]
    ls = labels[src_ref[...]]
    ld = labels[dst_ref[...]]
    o_ref[...] = jnp.minimum(ls, ld)


def edge_min(labels: jax.Array, src: jax.Array, dst: jax.Array) -> jax.Array:
    """m[e] = min(labels[src[e]], labels[dst[e]]) for an edge block."""
    if labels.shape != (BLOCK,) or src.shape != (BLOCK,) or dst.shape != (BLOCK,):
        raise ValueError("edge_min expects three (BLOCK,) arrays")
    grid = BLOCK // TILE
    return pl.pallas_call(
        _edge_min_kernel,
        grid=(grid,),
        in_specs=[
            # Full label array resident per step; edge tiles stream through.
            pl.BlockSpec((BLOCK,), lambda i: (0,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((BLOCK,), jnp.int32),
        interpret=True,
    )(labels.astype(jnp.int32), src.astype(jnp.int32), dst.astype(jnp.int32))
