"""Pure-jnp/numpy oracles for the Pallas kernels.

The kernels decode *integers*; the contract with the Rust runtime is exact
equality, not allclose - the tests assert both (allclose for the integer
arrays degenerates to equality, kept for harness uniformity).
"""

import numpy as np


def ref_gap_scan(gaps: np.ndarray, carry: int) -> np.ndarray:
    """out[i] = carry + sum(gaps[0..=i]), exact i64."""
    return np.cumsum(gaps.astype(np.int64)) + np.int64(carry)


def ref_edge_min(labels: np.ndarray, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """m[e] = min(labels[src[e]], labels[dst[e]])."""
    return np.minimum(labels[src], labels[dst]).astype(np.int32)


def ref_wcc_step(labels: np.ndarray, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """One full WCC label-propagation step (gather-min + scatter-min)."""
    m = ref_edge_min(labels, src, dst)
    out = labels.astype(np.int32).copy()
    np.minimum.at(out, src, m)
    np.minimum.at(out, dst, m)
    return out
