"""L1 Pallas kernel: blocked inclusive scan (gap -> absolute-ID decode).

This is the vectorizable phase-2 of WebGraph decompression: the Rust bit
parser (phase 1) emits, per decoded block, one concatenated array of i64
residual gaps whose inclusive prefix sum is the array of absolute neighbor
IDs. The paper's S6 calls for raising the decompression bandwidth `d`; this
kernel is that hot-spot expressed for a TPU-class programming model.

Hardware mapping (DESIGN.md SHardware-Adaptation):
  * the gap array is tiled into VMEM-sized chunks via BlockSpec
    (TILE i64 = 64 KiB per input tile);
  * each grid step performs an intra-tile inclusive scan on the VPU;
  * a (1,)-shaped VMEM scratch accumulator carries the running total across
    the *sequential* TPU grid - the classic scan decomposition
    (scan-per-tile + carry propagation) without a second kernel launch.

Run with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and correctness (exact integer equality vs. ref.py) is the
contract here.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Total block length served by the AOT executable; must match
# rust/src/runtime/exec.rs::GAP_SCAN_BLOCK.
BLOCK = 65_536
# VMEM tile: 8192 x 8 B = 64 KiB in, 64 KiB out, double-buffered.
TILE = 8_192


def _scan_kernel(carry_ref, x_ref, o_ref, acc_ref):
    """One grid step: inclusive scan of a TILE with the running carry."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        acc_ref[0] = carry_ref[0]

    tile = x_ref[...]
    scanned = jnp.cumsum(tile) + acc_ref[0]
    o_ref[...] = scanned
    acc_ref[0] = scanned[-1]


def gap_scan(gaps: jax.Array, carry: jax.Array) -> jax.Array:
    """Inclusive scan of `gaps` (i64[BLOCK]) offset by scalar i64 `carry`.

    Exact integer semantics: out[i] = carry + sum(gaps[0..=i]).
    """
    if gaps.shape != (BLOCK,):
        raise ValueError(f"gap_scan expects shape ({BLOCK},), got {gaps.shape}")
    grid = BLOCK // TILE
    return pl.pallas_call(
        _scan_kernel,
        grid=(grid,),
        in_specs=[
            # The scalar carry is visible to every step (SMEM-resident on
            # real hardware; only step 0 reads it).
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((BLOCK,), jnp.int64),
        scratch_shapes=[pltpu.VMEM((1,), jnp.int64)],
        interpret=True,
    )(carry.reshape(1).astype(jnp.int64), gaps.astype(jnp.int64))
