"""L1 Pallas kernels (build-time only; lowered into the L2 HLO modules)."""

from .gap_scan import BLOCK as GAP_SCAN_BLOCK, gap_scan  # noqa: F401
from .wcc_step import BLOCK as WCC_BLOCK, edge_min  # noqa: F401
