"""Build-time compile path (L2 jax model + L1 Pallas kernels + AOT driver).

Never imported at runtime: `make artifacts` runs `python -m compile.aot`
once; the Rust coordinator only reads the emitted artifacts/*.hlo.txt.
"""
