"""AOT driver: lower every L2 model to HLO text for the Rust runtime.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import json
import pathlib

import jax

# The gap-scan kernel is exact i64: x64 must be on before tracing.
jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    shapes = model.example_args()
    manifest = {}
    for name, fn in model.MODELS.items():
        lowered = jax.jit(fn).lower(*shapes[name])
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        manifest[name] = {
            "file": path.name,
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in shapes[name]
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
