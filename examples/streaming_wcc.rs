//! Use case B (§4.1 / §5.3): one pass over the edges, each edge processed
//! independently — streaming Jayanti–Tarjan WCC over asynchronously
//! delivered blocks, never holding the whole graph in memory.
//!
//! Also runs the XLA/Pallas label-propagation WCC when artifacts are built,
//! cross-checking all three engines against BFS ground truth.
//!
//! ```bash
//! cargo run --release --example streaming_wcc
//! ```

use std::sync::Arc;

use paragrapher::algorithms::bfs::wcc_by_bfs;
use paragrapher::algorithms::jtcc::JtUnionFind;
use paragrapher::algorithms::label_prop::{wcc_label_prop, StepEngine};
use paragrapher::algorithms::count_components;
use paragrapher::coordinator::{GraphType, Options, Paragrapher, VertexRange};
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::runtime::ArtifactSet;
use paragrapher::storage::{DeviceKind, SimStore};
use paragrapher::util::fmt_count;

fn main() -> anyhow::Result<()> {
    let data = Dataset::Rd.generate(1, 42);
    let truth = count_components(&wcc_by_bfs(&data));
    println!(
        "RD: {} vertices, {} edges — ground truth: {} components",
        fmt_count(data.num_vertices() as u64),
        fmt_count(data.num_edges()),
        truth
    );

    // Streaming JT-CC through ParaGrapher's async blocks on a slow device:
    // processing overlaps loading, memory stays at O(buffers × buffer_size).
    let store = Arc::new(SimStore::new(DeviceKind::Hdd));
    FormatKind::WebGraph.write_to_store(&data, &store, "rd");
    store.drop_cache();
    let pg = Paragrapher::init();
    let graph = pg.open_graph(
        Arc::clone(&store),
        "rd",
        GraphType::CsxWg400,
        Options { buffers: 3, buffer_edges: 8192, ..Options::default() },
    )?;
    let uf = Arc::new(JtUnionFind::new(graph.num_vertices(), 7));
    let uf2 = Arc::clone(&uf);
    let t0 = std::time::Instant::now();
    let req = graph.csx_get_subgraph(
        VertexRange::new(0, graph.num_vertices()),
        Arc::new(move |blk| {
            for (s, d) in blk.iter_edges() {
                uf2.union(s, d); // each edge exactly once, independently
            }
        }),
    )?;
    req.wait();
    anyhow::ensure!(!req.is_failed(), "load failed: {:?}", req.error());
    let jtcc_components = uf.count_components();
    println!(
        "JT-CC (streaming over async blocks): {} components in {:.3}s wall",
        jtcc_components,
        t0.elapsed().as_secs_f64()
    );
    assert_eq!(jtcc_components, truth);

    // Label-propagation WCC through the AOT-compiled XLA/Pallas step.
    match ArtifactSet::load(ArtifactSet::default_dir()) {
        Ok(arts) => {
            let labels = wcc_label_prop(&data, StepEngine::Xla(&arts))?;
            let xla_components = count_components(&labels);
            println!("label-prop WCC (XLA/Pallas wcc_step): {xla_components} components");
            assert_eq!(xla_components, truth);
        }
        Err(e) => println!("(skipping XLA label-prop: {e})"),
    }

    let labels = wcc_label_prop(&data, StepEngine::Native)?;
    println!("label-prop WCC (native step): {} components", count_components(&labels));
    println!("all engines agree with BFS ground truth ✓");
    Ok(())
}
