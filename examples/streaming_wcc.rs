//! Use case B (§4.1 / §5.3): streaming WCC on the *partitioned request
//! API* — edges are processed while later partitions load.
//!
//! Three engines over the same opened graph, all checked against BFS
//! ground truth:
//!
//! * streaming JT-CC draining a COO [`PartitionStream`] with two
//!   consumers (one pass, each edge exactly once, memory bounded by the
//!   prefetch window);
//! * partitioned min-label-propagation WCC (one stream per round — every
//!   round interleaves again);
//! * the XLA/Pallas label-propagation step, when artifacts are built.
//!
//! Ends with the §3 interleaved-vs-sequential comparison on the same
//! dataset: modeled end-to-end time with the pipeline vs load-then-execute.
//!
//! ```bash
//! cargo run --release --example streaming_wcc
//! ```

use std::sync::Arc;

use paragrapher::algorithms::bfs::wcc_by_bfs;
use paragrapher::algorithms::count_components;
use paragrapher::algorithms::label_prop::{wcc_label_prop, StepEngine};
use paragrapher::algorithms::partitioned::{wcc_jtcc_partitioned, wcc_label_prop_partitioned};
use paragrapher::bench::workloads::modeled_interleaved_run;
use paragrapher::coordinator::{GraphType, Options, Paragrapher};
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::partition::PartitionPlan;
use paragrapher::runtime::ArtifactSet;
use paragrapher::storage::{DeviceKind, SimStore};
use paragrapher::util::fmt_count;

const PARTS: usize = 8;
const CONSUMERS: usize = 2;

fn main() -> anyhow::Result<()> {
    let data = Dataset::Rd.generate(1, 42);
    let truth = count_components(&wcc_by_bfs(&data));
    println!(
        "RD: {} vertices, {} edges — ground truth: {} components",
        fmt_count(data.num_vertices() as u64),
        fmt_count(data.num_edges()),
        truth
    );

    // Open on a slow device: the point of partitioned streaming is that
    // union work overlaps the decode of later partitions.
    let store = Arc::new(SimStore::new(DeviceKind::Hdd));
    FormatKind::WebGraph.write_to_store(&data, &store, "rd");
    store.drop_cache();
    let pg = Paragrapher::init();
    let graph = pg.open_graph(
        Arc::clone(&store),
        "rd",
        GraphType::CsxWg400,
        Options { buffers: 3, buffer_edges: 8192, ..Options::default() },
    )?;
    let n = graph.num_vertices();

    // Streaming JT-CC: one COO-partitioned pass, CONSUMERS threads
    // pulling from the same stream (work-stealing hand-off).
    let t0 = std::time::Instant::now();
    let labels = wcc_jtcc_partitioned(|| graph.coo_get_partitions(PARTS), n, CONSUMERS, 7)?;
    let jtcc_components = count_components(&labels);
    println!(
        "JT-CC ({} COO partitions, {} consumers): {} components in {:.3}s wall",
        PARTS,
        CONSUMERS,
        jtcc_components,
        t0.elapsed().as_secs_f64()
    );
    assert_eq!(jtcc_components, truth);

    // Partitioned label propagation: each round re-opens a 1D stream.
    let labels = wcc_label_prop_partitioned(|| graph.csx_get_partitions(PARTS), n, CONSUMERS)?;
    let lp_components = count_components(&labels);
    println!("label-prop WCC (partitioned rounds): {lp_components} components");
    assert_eq!(lp_components, truth);

    // Label-propagation WCC through the AOT-compiled XLA/Pallas step.
    match ArtifactSet::load(ArtifactSet::default_dir()) {
        Ok(arts) => {
            let labels = wcc_label_prop(&data, StepEngine::Xla(&arts))?;
            let xla_components = count_components(&labels);
            println!("label-prop WCC (XLA/Pallas wcc_step): {xla_components} components");
            assert_eq!(xla_components, truth);
        }
        Err(e) => println!("(skipping XLA label-prop: {e})"),
    }

    // §3 interleaved-vs-sequential on this tier (modeled, deterministic):
    // the partitioned pipeline must sit strictly below load-then-execute
    // and inside the model envelope.
    let plan = PartitionPlan::one_d(graph.offsets_index(), PARTS);
    let run = modeled_interleaved_run(&store, "rd", &plan, graph.auto_prefetch_window(), 40.0)?;
    assert!(run.interleaved < run.sequential, "interleaving must win end-to-end");
    assert!(run.interleaved >= run.envelope_floor() - 1e-12, "inside the §3 envelope");
    println!(
        "interleaved {:.4}s vs load-then-execute {:.4}s — {:.2}× ({:.0}% of the smaller phase hidden)",
        run.interleaved,
        run.sequential,
        run.speedup(),
        run.overlap * 100.0
    );
    println!("all engines agree with BFS ground truth ✓");
    Ok(())
}
