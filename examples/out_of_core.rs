//! Use case D (§4.1): out-of-core processing — the graph does not fit in
//! memory, so blocks of consecutive edges are loaded, processed and
//! discarded. This example computes the degree distribution and total
//! triangle-adjacent wedge count of a graph while keeping at most
//! `buffers × buffer_edges` edges resident, and verifies the memory
//! ceiling actually holds.
//!
//! ```bash
//! cargo run --release --example out_of_core
//! ```

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use paragrapher::algorithms::bfs::{bfs_distances, bfs_distances_on};
use paragrapher::coordinator::{GraphType, Options, Paragrapher, VertexRange};
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::metrics::fmt_hit_rate;
use paragrapher::storage::{DeviceKind, SimStore};
use paragrapher::util::fmt_count;

fn main() -> anyhow::Result<()> {
    let data = Dataset::G5.generate(2, 42);
    let store = Arc::new(SimStore::new(DeviceKind::Hdd));
    FormatKind::WebGraph.write_to_store(&data, &store, "g5");
    store.drop_cache();

    // A deliberately tiny memory budget: 2 buffers × 16Ki edges, far below
    // the graph's edge count — the paper's "-1 Out of Memory" scenario for
    // full-load frameworks, which ParaGrapher sidesteps by partial loading.
    let buffers = 2usize;
    let buffer_edges = 4 << 10;
    println!(
        "G5: {} edges; resident budget = {} edges ({}x{})",
        fmt_count(data.num_edges()),
        fmt_count((buffers as u64) * buffer_edges),
        buffers,
        fmt_count(buffer_edges),
    );
    assert!(
        (buffers as u64) * buffer_edges < data.num_edges() / 4,
        "budget must be far below graph size for the demo to mean anything"
    );

    let pg = Paragrapher::init();
    let graph = pg.open_graph(
        Arc::clone(&store),
        "g5",
        GraphType::CsxWg400,
        Options {
            buffers,
            buffer_edges,
            // Hold the random-access path to the same resident budget as
            // the streaming buffers (cost units ≈ edges).
            source_cache_cost: (buffers as u64) * buffer_edges,
            ..Options::default()
        },
    )?;

    // Out-of-core pass: histogram of degrees + wedge count, O(|V|) state.
    let wedges = Arc::new(AtomicU64::new(0));
    let max_resident = Arc::new(AtomicUsize::new(0));
    let hist = Arc::new((0..64).map(|_| AtomicU64::new(0)).collect::<Vec<_>>());
    let (w2, m2, h2) = (Arc::clone(&wedges), Arc::clone(&max_resident), Arc::clone(&hist));
    let req = graph.csx_get_subgraph(
        VertexRange::new(0, graph.num_vertices()),
        Arc::new(move |blk| {
            m2.fetch_max(blk.edges.len(), Ordering::Relaxed);
            for i in 0..blk.num_vertices() {
                let deg = blk.neighbors(blk.start_vertex + i).len() as u64;
                let bucket = (64 - deg.leading_zeros() as usize).min(63);
                h2[bucket].fetch_add(1, Ordering::Relaxed);
                w2.fetch_add(deg * deg.saturating_sub(1) / 2, Ordering::Relaxed);
            }
        }),
    )?;
    req.wait();
    anyhow::ensure!(!req.is_failed(), "load failed: {:?}", req.error());

    println!(
        "processed {} edges in {} blocks; peak block size seen: {} edges",
        fmt_count(req.edges_delivered()),
        req.total_blocks(),
        fmt_count(max_resident.load(Ordering::Relaxed) as u64),
    );
    println!("wedge count: {}", fmt_count(wedges.load(Ordering::Relaxed)));
    println!("degree histogram (log2 buckets):");
    for (b, c) in hist.iter().enumerate() {
        let count = c.load(Ordering::Relaxed);
        if count > 0 {
            println!("  2^{:>2}..: {:>8}", b.saturating_sub(1), count);
        }
    }

    // The whole point: the peak resident block never exceeded the budget
    // (plus one oversized vertex allowance).
    let peak = max_resident.load(Ordering::Relaxed) as u64;
    let max_degree =
        (0..data.num_vertices()).map(|v| data.degree(v as u32)).max().unwrap_or(0);
    assert!(
        peak <= buffer_edges.max(max_degree),
        "peak {peak} exceeded budget {buffer_edges} (max degree {max_degree})"
    );
    println!("memory ceiling held: peak block {peak} ≤ budget {buffer_edges} ✓");

    // The same opened handle also serves per-vertex *random access*
    // (GraphSource): BFS pulls each frontier neighborhood on demand through
    // the decoded-block cache — the second out-of-core request type, no
    // full load anywhere.
    let dist = bfs_distances_on(&graph, 0)?;
    let reached = dist.iter().filter(|&&d| d != u32::MAX).count();
    let cache = graph.decoded_cache_counters();
    println!(
        "random-access BFS from vertex 0: reached {} of {} vertices",
        fmt_count(reached as u64),
        fmt_count(graph.num_vertices() as u64),
    );
    println!(
        "decoded-block cache: {} ({} hits / {} misses, {} evictions)",
        fmt_hit_rate(&cache),
        cache.hits,
        cache.misses,
        cache.evictions,
    );
    assert_eq!(dist, bfs_distances(&data, 0), "random access must match full-load BFS");
    println!("random-access BFS matches the full-load oracle ✓");
    Ok(())
}
