//! Use case C (§4.1), now on *real processes*: the leader computes an
//! edge-balanced 2D [`PartitionPlan`] from the O(|V|) offsets sidecar
//! alone (§6: "loading from storage instead of processing"), serializes
//! it over a length-prefixed socket, and every worker — a separate OS
//! process re-spawned from this same binary — opens the on-disk graph
//! itself, admits the shipped plan against its *own* Elias–Fano sidecar,
//! decodes leased tiles through its own coordinator, and streams
//! per-tile edge summaries back.
//!
//! The second run injects a deterministic fault (`kill-worker:0`
//! mid-tile) to show the lease/retile protocol: the leader observes the
//! transport EOF, returns the orphaned tiles to the pending pool, and
//! the survivor finishes them — full edge coverage, checked tile-by-tile
//! against the single-process full-load oracle.
//!
//! ```bash
//! cargo run --release --example distributed_partition
//! ```

use paragrapher::coordinator::{GraphType, Options, Paragrapher};
use paragrapher::distributed::{
    oracle_tile_summaries, run_leader, run_worker, LeaderConfig, RunReport, WorkerConfig,
};
use paragrapher::formats::webgraph;
use paragrapher::graph::generators::Dataset;
use paragrapher::storage::DeviceKind;
use paragrapher::util::fmt_count;

const WORKERS: usize = 2;
const TILES: usize = 4; // 4×4 source×target grid

fn check_against_oracle(report: &RunReport, oracle: &[(u64, u64)]) {
    for t in &report.tiles {
        assert_eq!(
            (t.edges, t.checksum),
            oracle[t.tile],
            "tile {} disagrees with the single-process oracle",
            t.tile
        );
    }
}

fn main() -> anyhow::Result<()> {
    // Worker mode: the leader re-spawns this same example binary with
    // `worker --connect … --dir …` argv; everything after the subcommand
    // is the worker's own flag set.
    let args: Vec<String> = std::env::args().collect();
    if args.get(1).map(String::as_str) == Some("worker") {
        return run_worker(&WorkerConfig::from_args(&args[2..])?);
    }

    // Leader: write a real on-disk fixture every process opens
    // independently (the paper's shared-filesystem cluster shape).
    let data = Dataset::Cw.generate(1, 42);
    let dir = std::env::temp_dir().join(format!("pg_example_dist_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    for (name, bytes) in webgraph::serialize(&data, "cw") {
        std::fs::write(dir.join(&name), &bytes)?;
    }
    let exe = std::env::current_exe()?;
    let mut cfg = LeaderConfig::new(
        &dir,
        "cw",
        GraphType::CsxWg400,
        DeviceKind::Ssd,
        vec![exe.to_string_lossy().into_owned(), "worker".to_string()],
    );
    cfg.workers = WORKERS;
    cfg.rows = TILES;
    cfg.cols = TILES;

    // Run 1: clean two-process load.
    let clean = run_leader(&cfg)?;
    println!(
        "CW: {}×{} tiles over {} worker processes — {} edges delivered in {:.2}s",
        TILES,
        TILES,
        clean.workers_spawned,
        fmt_count(clean.edges_delivered),
        clean.wall_seconds,
    );

    // Single-process oracle over the *same* shipped plan.
    let pg = Paragrapher::init();
    let graph = pg.open_graph_from_dir(
        &dir,
        DeviceKind::Ssd,
        "cw",
        GraphType::CsxWg400,
        Options::default(),
    )?;
    let oracle = oracle_tile_summaries(&graph, clean.plan.clone())?;
    pg.release_graph(graph);
    check_against_oracle(&clean, &oracle);
    println!("every tile matches the single-process full-load oracle ✓");

    // Run 2: worker 0 is killed after its first tile, mid-second-tile.
    // The leader retiles the orphaned span across the survivor.
    cfg.fault_args = vec![(0, "kill-after:1".to_string())];
    let faulted = run_leader(&cfg)?;
    assert!(faulted.workers_lost >= 1, "fault injection lost no worker");
    assert!(faulted.retiled_tiles >= 1, "worker death retiled no tiles");
    check_against_oracle(&faulted, &oracle);
    println!(
        "fault run: {} worker lost mid-tile, {} tile(s) retiled to survivors — coverage and \
         checksums still match the oracle ✓",
        faulted.workers_lost, faulted.retiled_tiles,
    );

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
