//! Use case C (§4.1): distributed-memory loading — each "machine" loads a
//! contiguous block of edges. Partitioning uses only the O(|V|) offsets
//! sidecar (§6: "loading from storage instead of processing"), then every
//! machine selectively decodes exactly its share, in parallel, and a
//! leader merges per-machine results (here: a distributed degree sum and
//! per-partition WCC forests merged at the boundary).
//!
//! ```bash
//! cargo run --release --example distributed_partition
//! ```

use std::sync::Arc;

use paragrapher::algorithms::jtcc::JtUnionFind;
use paragrapher::coordinator::{GraphType, Options, Paragrapher, VertexRange};
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::storage::{DeviceKind, SimStore};
use paragrapher::util::fmt_count;

const MACHINES: usize = 4;

fn main() -> anyhow::Result<()> {
    let data = Dataset::Cw.generate(1, 42);
    let store = Arc::new(SimStore::new(DeviceKind::Nas)); // shared NAS, like the paper's cluster
    FormatKind::WebGraph.write_to_store(&data, &store, "cw");
    store.drop_cache();

    let pg = Paragrapher::init();
    let graph = pg.open_graph(
        Arc::clone(&store),
        "cw",
        GraphType::CsxWg400,
        Options { buffers: 2, buffer_edges: 32 << 10, ..Options::default() },
    )?;
    let n = graph.num_vertices();
    let m = graph.num_edges();

    // 1. Partition by edge count using ONLY the offsets sidecar.
    let offsets = graph.csx_get_offsets(0, n)?;
    let mut boundaries = vec![0usize];
    for k in 1..MACHINES {
        let target = m * k as u64 / MACHINES as u64;
        boundaries.push(offsets.partition_point(|&e| e < target).min(n));
    }
    boundaries.push(n);
    println!("CW: {} vertices, {} edges over {MACHINES} machines", fmt_count(n as u64), fmt_count(m));
    for w in boundaries.windows(2).enumerate() {
        let (k, w) = w;
        let edges = offsets[w[1]] - offsets[w[0]];
        println!(
            "  machine {k}: vertices [{}, {}) — {} edges",
            w[0],
            w[1],
            fmt_count(edges)
        );
    }

    // 2. Every machine selectively loads its own contiguous range and
    //    builds a local union-find over the global vertex space.
    let global_uf = Arc::new(JtUnionFind::new(n, 3));
    let mut per_machine_edges = vec![0u64; MACHINES];
    std::thread::scope(|scope| -> anyhow::Result<()> {
        let mut handles = Vec::new();
        for k in 0..MACHINES {
            let (lo, hi) = (boundaries[k], boundaries[k + 1]);
            let graph = &graph;
            let uf = Arc::clone(&global_uf);
            handles.push(scope.spawn(move || -> anyhow::Result<u64> {
                let block = graph.csx_get_subgraph_sync(VertexRange::new(lo, hi))?;
                // "Machine-local" processing: union edges of this partition.
                for i in 0..block.num_vertices() {
                    let v = (lo + i) as u32;
                    for &d in block.neighbors(i) {
                        uf.union(v, d);
                    }
                }
                Ok(block.num_edges())
            }));
        }
        for (k, h) in handles.into_iter().enumerate() {
            per_machine_edges[k] = h.join().expect("machine thread")?;
        }
        Ok(())
    })?;

    // 3. Leader check: all edges exactly covered, WCC matches truth.
    let total: u64 = per_machine_edges.iter().sum();
    assert_eq!(total, m, "machines must cover every edge exactly once");
    let components = global_uf.count_components();
    let truth = paragrapher::algorithms::count_components(
        &paragrapher::algorithms::bfs::wcc_by_bfs(&data),
    );
    assert_eq!(components, truth);
    println!(
        "leader: {} edges loaded across machines; {} components (matches ground truth ✓)",
        fmt_count(total),
        components
    );
    Ok(())
}
