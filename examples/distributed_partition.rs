//! Use case C (§4.1): distributed-memory loading on the *partitioned
//! request API* — the leader computes an edge-balanced 2D
//! [`PartitionPlan`] from the O(|V|) offsets sidecar alone (§6: "loading
//! from storage instead of processing"), ships its serializable metadata,
//! and every "machine" (consumer thread) drains the same
//! [`PartitionStream`]: tiles are decoded asynchronously ahead of
//! consumption (prefetch window sized by the §3 LoadModel) and handed to
//! whichever machine pulls next, while each machine folds its tiles into
//! a shared union-find. The leader then checks exact edge coverage and
//! WCC agreement with ground truth.
//!
//! ```bash
//! cargo run --release --example distributed_partition
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use paragrapher::algorithms::jtcc::JtUnionFind;
use paragrapher::algorithms::partitioned::for_each_partition;
use paragrapher::coordinator::{GraphType, Options, Paragrapher};
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::partition::PartitionPlan;
use paragrapher::storage::{DeviceKind, SimStore};
use paragrapher::util::fmt_count;

const MACHINES: usize = 4;

fn main() -> anyhow::Result<()> {
    let data = Dataset::Cw.generate(1, 42);
    let store = Arc::new(SimStore::new(DeviceKind::Nas)); // shared NAS, like the paper's cluster
    FormatKind::WebGraph.write_to_store(&data, &store, "cw");
    store.drop_cache();

    let pg = Paragrapher::init();
    let graph = pg.open_graph(
        Arc::clone(&store),
        "cw",
        GraphType::CsxWg400,
        Options { buffers: 2, buffer_edges: 32 << 10, ..Options::default() },
    )?;
    let n = graph.num_vertices();
    let m = graph.num_edges();

    // 1. Leader: an edge-balanced source×target tiling from the sidecar
    //    index alone — O(p log n), no graph data touched. The plan is
    //    plain serializable metadata a leader would ship to machines.
    let plan = PartitionPlan::two_d(graph.offsets_index(), MACHINES, MACHINES);
    println!(
        "CW: {} vertices, {} edges — {}×{} tiles, balance factor {:.3}, prefetch window {}",
        fmt_count(n as u64),
        fmt_count(m),
        MACHINES,
        MACHINES,
        plan.balance_factor(),
        graph.auto_prefetch_window(),
    );

    // 2. Machines: MACHINES consumer threads drain one partitioned
    //    request. Tiles decode ahead of consumption; each machine unions
    //    its tiles' edges into the shared forest (work-stealing hand-off:
    //    a slow machine never blocks the others).
    let stream = graph.get_partitions(plan.clone())?;
    let global_uf = Arc::new(JtUnionFind::new(n, 3));
    let tile_edges = AtomicU64::new(0);
    let uf = Arc::clone(&global_uf);
    for_each_partition(&stream, MACHINES, |tile| {
        tile_edges.fetch_add(tile.num_edges(), Ordering::Relaxed);
        for (s, d) in tile.iter_edges() {
            uf.union(s, d);
        }
        Ok(())
    })?;

    // 3. Leader merge checks: every edge delivered exactly once across
    //    all tiles, and the distributed WCC matches ground truth.
    let total = tile_edges.load(Ordering::Relaxed);
    assert_eq!(total, m, "tiles must cover every edge exactly once");
    let components = global_uf.count_components();
    let truth = paragrapher::algorithms::count_components(
        &paragrapher::algorithms::bfs::wcc_by_bfs(&data),
    );
    assert_eq!(components, truth);
    let c = stream.counters();
    println!(
        "machines: {} edges over {} tiles; {} components (matches ground truth ✓)",
        fmt_count(total),
        c.consumed,
        components
    );
    println!(
        "interleaving: {:.1}% prefetch hit rate, {} consumer stalls, {} producer stalls",
        c.prefetch_hit_rate() * 100.0,
        c.consumer_stalls,
        c.producer_stalls
    );
    // Machine-readable health record (what a leader would log per epoch).
    println!(
        "partition metrics: {}",
        paragrapher::metrics::partition_report(&plan, &c, None).to_string_pretty()
    );
    Ok(())
}
