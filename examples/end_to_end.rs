//! END-TO-END DRIVER — the full pipeline on the scaled dataset suite,
//! producing the paper's headline numbers (shape-level): loading
//! throughput per format/device (Fig. 5), end-to-end WCC (Fig. 6), and
//! the load speedups ("up to 3.2× loading, up to 5.2× end-to-end").
//!
//! Pipeline per dataset: generate → serialize in all four formats →
//! cold-load each through its real loader on calibrated device models →
//! stream JT-CC through ParaGrapher (XLA/Pallas scan engine when
//! artifacts are present) vs full-load + Afforest for the baselines.
//!
//! ```bash
//! cargo run --release --example end_to_end        # scale 1
//! SCALE=2 cargo run --release --example end_to_end
//! ```
//!
//! Results are recorded in EXPERIMENTS.md.

use std::sync::Arc;
use std::time::Instant;

use paragrapher::algorithms::{afforest::afforest, count_components, jtcc::JtUnionFind};
use paragrapher::coordinator::{GraphType, Options, Paragrapher, VertexRange};
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::graph::CsrGraph;
use paragrapher::metrics::{fmt_bw, fmt_meps, LoadMeasurement, Table};
use paragrapher::model::LoadModel;
use paragrapher::runtime::{ArtifactSet, XlaScanEngine};
use paragrapher::storage::sim::ReadCtx;
use paragrapher::storage::{DeviceKind, IoAccount, SimStore};
use paragrapher::util::{fmt_bytes, fmt_count};

const THREADS: usize = 4;
/// Baseline frameworks load the whole uncompressed graph: this models the
/// paper's OOM bars ("-1") when it exceeds the memory budget.
const MEMORY_BUDGET_BYTES: u64 = 1 << 30;

fn main() -> anyhow::Result<()> {
    let scale: usize =
        std::env::var("SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(4);
    let t_all = Instant::now();
    let artifacts = ArtifactSet::load(ArtifactSet::default_dir()).ok();
    match &artifacts {
        Some(a) => println!(
            "XLA runtime: platform {} (artifacts: {})",
            a.platform().unwrap_or_default(),
            a.dir().display()
        ),
        None => println!("XLA runtime: artifacts not built — native scan engine only"),
    }

    let devices = [DeviceKind::Hdd, DeviceKind::Ssd, DeviceKind::Nas];
    let mut best_load_speedup = (0.0f64, String::new());
    let mut best_e2e_speedup = (0.0f64, String::new());

    for dataset in Dataset::ALL {
        let data = dataset.generate(scale, 42);
        println!(
            "\n################ {} — |V| {} |E| {} ################",
            dataset.abbr(),
            fmt_count(data.num_vertices() as u64),
            fmt_count(data.num_edges()),
        );

        for device in devices {
            let mut load_table = Table::new(&["format", "load ME/s", "device bw", "e2e WCC s"]);
            let mut meps: Vec<(FormatKind, f64, f64)> = Vec::new();
            for format in FormatKind::ALL {
                let store = Arc::new(SimStore::new_scaled(device));
                let base = dataset.abbr().to_string();
                let stored = format.write_to_store(&data, &store, &base);
                store.drop_cache();

                // OOM check for full-load baselines (uncompressed in-memory
                // size: offsets + edges).
                let in_memory =
                    (data.num_vertices() as u64 + 1) * 8 + data.num_edges() * 4;
                if format != FormatKind::WebGraph && in_memory > MEMORY_BUDGET_BYTES {
                    load_table.row(&[
                        format.name().into(),
                        "-1 (OOM)".into(),
                        "-".into(),
                        "-1 (OOM)".into(),
                    ]);
                    continue;
                }

                let (load, e2e) = match format {
                    FormatKind::WebGraph => {
                        run_paragrapher(&data, Arc::clone(&store), &base, &artifacts)?
                    }
                    _ => run_baseline(&data, &store, &base, format)?,
                };
                load_table.row(&[
                    format.name().into(),
                    fmt_meps(load.me_per_sec()),
                    fmt_bw(load.device_bandwidth()),
                    format!("{:.3}", e2e),
                ]);
                meps.push((format, load.me_per_sec(), e2e));
                let _ = stored;
            }
            println!("\n{} / {} (modeled):", dataset.abbr(), device.name());
            print!("{}", load_table.render());

            // Speedups vs best baseline (the paper compares against GAPBS
            // Bin CSX and Txt COO).
            let wg = meps.iter().find(|(f, _, _)| *f == FormatKind::WebGraph);
            let bin = meps.iter().find(|(f, _, _)| *f == FormatKind::BinCsx);
            if let (Some(&(_, wg_meps, wg_e2e)), Some(&(_, bin_meps, bin_e2e))) = (wg, bin)
            {
                let ls = wg_meps / bin_meps;
                let es = bin_e2e / wg_e2e;
                println!(
                    "  speedup vs Bin CSX: load {ls:.2}x, end-to-end {es:.2}x"
                );
                let tag = format!("{}/{}", dataset.abbr(), device.name());
                if ls > best_load_speedup.0 {
                    best_load_speedup = (ls, tag.clone());
                }
                if es > best_e2e_speedup.0 {
                    best_e2e_speedup = (es, tag);
                }
            }
        }

        // §3 model check for this dataset on HDD: measured load bandwidth
        // must respect b ≤ min(σ·r, d).
        let store = Arc::new(SimStore::new_scaled(DeviceKind::Hdd));
        let base = dataset.abbr().to_string();
        FormatKind::WebGraph.write_to_store(&data, &store, &base);
        let compressed = FormatKind::WebGraph.stored_bytes(&store, &base);
        let uncompressed = (data.num_vertices() as u64 + 1) * 8 + data.num_edges() * 4;
        let r = uncompressed as f64 / compressed as f64;
        println!(
            "  compression: {} -> {} (r = {r:.1})",
            fmt_bytes(uncompressed),
            fmt_bytes(compressed)
        );
        let model = LoadModel { sigma: 160e6, r, d: f64::INFINITY };
        println!(
            "  §3 envelope on HDD: b ≤ σ·r = {} ({} uncompressed-equivalent)",
            fmt_bw(model.upper_bound()),
            fmt_meps(model.upper_bound() / 4.0 / 1e6),
        );
    }

    println!("\n================ HEADLINE ================");
    println!(
        "max load speedup vs Bin CSX:      {:.2}x ({})   [paper: up to 3.2x]",
        best_load_speedup.0, best_load_speedup.1
    );
    println!(
        "max end-to-end speedup (WCC):     {:.2}x ({})   [paper: up to 5.2x]",
        best_e2e_speedup.0, best_e2e_speedup.1
    );
    println!("total driver time: {:.1}s", t_all.elapsed().as_secs_f64());
    Ok(())
}

/// ParaGrapher path: the real coordinator streams blocks into JT-CC for
/// correctness, while the reported times come from the virtual-clock load
/// model (the same composition the baselines use, so speedups compare
/// like with like — the host may have a single core, which would otherwise
/// serialize "parallel" wall-clock decode).
fn run_paragrapher(
    data: &CsrGraph,
    store: Arc<SimStore>,
    base: &str,
    artifacts: &Option<Arc<ArtifactSet>>,
) -> anyhow::Result<(LoadMeasurement, f64)> {
    // (a) Correctness pass through the actual coordinator (async callbacks,
    // buffer protocol, XLA scan engine when available).
    let pg = Paragrapher::init();
    // Blocks must comfortably outnumber workers for load balance (the
    // paper's 64M-edge buffers vs multi-billion-edge graphs give 40-2000
    // blocks; scale the same ratio down).
    let buffer_edges = (data.num_edges() / (4 * THREADS as u64)).max(8 << 10);
    let mut opts = Options {
        buffers: THREADS,
        buffer_edges,
        read_ctx: ReadCtx { threads: THREADS, ..ReadCtx::default() },
        ..Options::default()
    };
    if let Some(arts) = artifacts {
        opts.scan = Arc::new(XlaScanEngine::new(Arc::clone(arts)));
    }
    let graph = pg.open_graph(Arc::clone(&store), base, GraphType::CsxWg400, opts)?;
    let uf = Arc::new(JtUnionFind::new(graph.num_vertices(), 7));
    let uf2 = Arc::clone(&uf);
    let req = graph.csx_get_subgraph(
        VertexRange::new(0, graph.num_vertices()),
        Arc::new(move |blk| {
            for (s, d) in blk.iter_edges() {
                uf2.union(s, d);
            }
        }),
    )?;
    req.wait();
    anyhow::ensure!(!req.is_failed(), "streaming load failed: {:?}", req.error());
    anyhow::ensure!(req.edges_delivered() == data.num_edges(), "decode mismatch");
    let _ = uf.count_components();
    pg.release_graph(graph);

    // (b) Modeled load throughput (use case A) on the same store.
    store.drop_cache();
    let r = paragrapher::bench::workloads::modeled_paragrapher_load(
        &store,
        base,
        THREADS,
        buffer_edges,
        &paragrapher::runtime::NativeScan,
        100e-6,
        None,
    )?;
    let load = r.measurement;

    // (c) Modeled end-to-end WCC: one JT-CC pass overlapped with loading
    // (§3's overlap: the slower of decode-stream vs union work dominates).
    let uf = JtUnionFind::new(data.num_vertices(), 7);
    let t0 = Instant::now();
    for (s, d) in data.iter_edges() {
        uf.union(s, d);
    }
    let union_cpu = t0.elapsed().as_secs_f64();
    let e2e = r.sequential_seconds + r.parallel_seconds.max(union_cpu / THREADS as f64);
    Ok((load, e2e))
}

/// Baseline path: full parallel load (GAPBS-style reader) + Afforest.
fn run_baseline(
    data: &CsrGraph,
    store: &SimStore,
    base: &str,
    format: FormatKind,
) -> anyhow::Result<(LoadMeasurement, f64)> {
    let accounts: Vec<IoAccount> = (0..THREADS).map(|_| IoAccount::new()).collect();
    let ctx = ReadCtx { threads: THREADS, ..ReadCtx::default() };
    let loaded = format.load_full(store, base, ctx, &accounts)?;
    anyhow::ensure!(loaded.num_edges() == data.num_edges(), "load mismatch");
    let load = LoadMeasurement::from_accounts(&accounts, loaded.num_edges(), 0.0);

    // End-to-end: the load happens again cold (fresh accounts), then the
    // algorithm runs on the in-memory graph.
    store.drop_cache();
    let accounts2: Vec<IoAccount> = (0..THREADS).map(|_| IoAccount::new()).collect();
    let loaded2 = format.load_full(store, base, ctx, &accounts2)?;
    let t0 = Instant::now();
    let labels = afforest(&loaded2, 7);
    let algo = t0.elapsed().as_secs_f64();
    let _ = count_components(&labels);
    let e2e =
        LoadMeasurement::from_accounts(&accounts2, loaded2.num_edges(), algo).elapsed;
    Ok((load, e2e))
}
