//! Quickstart: open a compressed graph and load it, synchronously and
//! asynchronously — Figures 2 and 3 of the paper as running code.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use paragrapher::coordinator::{GraphType, Options, Paragrapher, VertexRange};
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::storage::{DeviceKind, SimStore};
use paragrapher::util::fmt_count;

fn main() -> anyhow::Result<()> {
    // 1. A dataset in WebGraph format on a simulated SSD.
    let graph_data = Dataset::Tw.generate(1, 42);
    let store = Arc::new(SimStore::new(DeviceKind::Ssd));
    FormatKind::WebGraph.write_to_store(&graph_data, &store, "tw");
    store.drop_cache();
    println!(
        "dataset TW: {} vertices, {} edges (WebGraph: {} bytes on storage)",
        fmt_count(graph_data.num_vertices() as u64),
        fmt_count(graph_data.num_edges()),
        FormatKind::WebGraph.stored_bytes(&store, "tw"),
    );

    // 2. paragrapher_init + open_graph.
    let pg = Paragrapher::init();
    let graph = pg.open_graph(
        Arc::clone(&store),
        "tw",
        GraphType::CsxWg400,
        Options { buffers: 4, buffer_edges: 1 << 16, ..Options::default() },
    )?;

    // 3. Synchronous (blocking) call — Fig. 2: the library parallelizes
    //    loading while we wait for the whole subgraph at once.
    let block = graph.csx_get_subgraph_sync(VertexRange::new(0, 1000))?;
    println!(
        "sync: vertices [0, 1000) carry {} edges; vertex 0 has degree {}",
        fmt_count(block.num_edges()),
        block.neighbors(0).len(),
    );

    // 4. Asynchronous (non-blocking) call — Fig. 3: the call returns
    //    immediately; the callback receives each decoded block.
    let edges_seen = Arc::new(AtomicU64::new(0));
    let e2 = Arc::clone(&edges_seen);
    let request = graph.csx_get_subgraph(
        VertexRange::new(0, graph.num_vertices()),
        Arc::new(move |blk| {
            // Process edges as soon as the first block arrives.
            e2.fetch_add(blk.num_edges(), Ordering::Relaxed);
        }),
    )?;
    println!(
        "async: call returned immediately ({} of {} blocks done)",
        request.blocks_done(),
        request.total_blocks(),
    );
    request.wait();
    println!(
        "async: completed; callbacks saw {} edges",
        fmt_count(edges_seen.load(Ordering::Relaxed)),
    );

    // 5. O(|V|) offsets access without touching edge data (§6).
    let offsets = graph.csx_get_offsets(0, 10)?;
    println!("first ten offsets: {offsets:?}");

    // 6. Release: joins library threads, drops the OS cache (§4.1).
    pg.release_graph(graph);
    println!("released — resources restored");
    Ok(())
}
